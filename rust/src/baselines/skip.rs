//! SKIP (Gardner et al. 2018b): product kernel interpolation — the
//! paper's main scalable-SKI comparator (Table 2, Fig. 5).
//!
//! Per dimension j, the 1-D kernel is approximated by 1-D KISS
//! (K^(j) = W_j T_j W_jᵀ, grid of 100 points per the paper's setup),
//! compressed to a rank-r PSD factor L_j L_jᵀ via Lanczos; the full
//! kernel is the Hadamard product ⊙_j K^(j) (exact for RBF, which
//! factors across dimensions). Pairs are merged up a binary tree, each
//! merge re-truncated to rank r with Lanczos on the merge operator
//! (A ⊙ B)v = Σ_p a_p ⊙ (B (a_p ⊙ v)) — this is where SKIP's low-rank
//! bottleneck (and its memory appetite, ~r Lanczos basis vectors of
//! length n per level) comes from.

use anyhow::{ensure, Result};

use crate::kernels::ArdKernel;
use crate::linalg::{eigh_tridiag, Mat, SymToeplitz};
use crate::mvm::MvmOperator;
use crate::solvers::lanczos;
use crate::util::Pcg64;

/// Rank-r PSD factor: K ≈ L Lᵀ (L is n×r, stored row-major).
#[derive(Clone)]
pub struct LowRankPsd {
    pub l: Mat,
}

impl LowRankPsd {
    pub fn mvm(&self, v: &[f64]) -> Vec<f64> {
        let ltv = self.l.matvec_t(v);
        self.l.matvec(&ltv)
    }

    pub fn rank(&self) -> usize {
        self.l.cols
    }
}

/// Hadamard-product operator of two PSD factors (used during merging).
struct HadamardOp<'a> {
    a: &'a LowRankPsd,
    b: &'a LowRankPsd,
}

impl MvmOperator for HadamardOp<'_> {
    fn len(&self) -> usize {
        self.a.l.rows
    }
    fn mvm(&self, v: &[f64]) -> Vec<f64> {
        let n = self.len();
        let mut out = vec![0.0; n];
        // (A ⊙ B) v = Σ_p a_p ⊙ (B (a_p ⊙ v)).
        for p in 0..self.a.rank() {
            let ap: Vec<f64> = (0..n).map(|i| self.a.l[(i, p)]).collect();
            let scaled: Vec<f64> = (0..n).map(|i| ap[i] * v[i]).collect();
            let bv = self.b.mvm(&scaled);
            for i in 0..n {
                out[i] += ap[i] * bv[i];
            }
        }
        out
    }
}

/// Compress a symmetric PSD operator to rank r with Lanczos: run r
/// steps, eigendecompose the tridiagonal, keep non-negative Ritz pairs.
fn lanczos_compress(op: &dyn MvmOperator, r: usize, rng: &mut Pcg64) -> LowRankPsd {
    let n = op.len();
    let q0 = rng.normal_vec(n);
    let res = lanczos(op, &q0, r, true);
    let basis = res.q.unwrap();
    let t = res.alpha.len();
    let (evals, evecs) = eigh_tridiag(&res.alpha, &res.beta);
    // L = Q · U · Λ^{1/2}, keeping positive eigenvalues.
    let mut l = Mat::zeros(n, t);
    for j in 0..t {
        let lam = evals[j].max(0.0);
        let s = lam.sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..t.min(basis.len()) {
                acc += basis[k][i] * evecs[(k, j)];
            }
            l[(i, j)] = acc * s;
        }
    }
    LowRankPsd { l }
}

/// 1-D KISS operator for one input dimension (grid + Toeplitz).
struct Kiss1d {
    idx: Vec<usize>,
    frac: Vec<f64>,
    toeplitz: SymToeplitz,
    n: usize,
}

impl Kiss1d {
    fn build(coords: &[f64], kernel_profile: impl Fn(f64) -> f64, grid: usize) -> Self {
        let n = coords.len();
        let lo = coords.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = coords.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-9);
        let step = span / (grid as f64 - 1.0);
        let col: Vec<f64> = (0..grid).map(|t| kernel_profile(t as f64 * step)).collect();
        let toeplitz = SymToeplitz::new(col);
        let mut idx = vec![0usize; n];
        let mut frac = vec![0.0; n];
        for i in 0..n {
            let t = ((coords[i] - lo) / step).clamp(0.0, grid as f64 - 1.0 - 1e-9);
            idx[i] = t.floor() as usize;
            frac[i] = t - idx[i] as f64;
        }
        Kiss1d {
            idx,
            frac,
            toeplitz,
            n,
        }
    }
}

impl MvmOperator for Kiss1d {
    fn len(&self) -> usize {
        self.n
    }
    fn mvm(&self, v: &[f64]) -> Vec<f64> {
        let m = self.toeplitz.len();
        let mut z = vec![0.0; m];
        for i in 0..self.n {
            z[self.idx[i]] += (1.0 - self.frac[i]) * v[i];
            z[self.idx[i] + 1] += self.frac[i] * v[i];
        }
        let z = self.toeplitz.matvec(&z);
        (0..self.n)
            .map(|i| (1.0 - self.frac[i]) * z[self.idx[i]] + self.frac[i] * z[self.idx[i] + 1])
            .collect()
    }
}

/// The SKIP MVM operator: merged rank-r factor for ⊙_j K^(j).
pub struct SkipMvm {
    pub d: usize,
    pub n: usize,
    pub rank: usize,
    pub outputscale: f64,
    factor: LowRankPsd,
    /// Peak bytes held during construction (Fig. 5 accounting: SKIP's
    /// memory appetite comes from the per-level Lanczos bases).
    pub peak_build_bytes: usize,
}

impl SkipMvm {
    /// Build with rank `r` (paper: 20–100) and 100 grid points per dim.
    pub fn build(
        x: &[f64],
        d: usize,
        kernel: &ArdKernel,
        rank: usize,
        seed: u64,
    ) -> Result<Self> {
        ensure!(x.len() % d == 0, "shape");
        let n = x.len() / d;
        ensure!(n >= 2 && rank >= 2, "need n, rank >= 2");
        let grid = 100usize.min(4 * n.max(2));
        let mut rng = Pcg64::new(seed ^ 0x5717);
        // Per-dimension rank-r factors.
        let mut level: Vec<LowRankPsd> = (0..d)
            .map(|j| {
                let coords: Vec<f64> = (0..n).map(|i| x[i * d + j]).collect();
                let ell = kernel.lengthscales[j];
                let fam = kernel.family;
                let k1 = Kiss1d::build(
                    &coords,
                    move |tau| {
                        let t = tau / ell;
                        fam.profile(t * t)
                    },
                    grid,
                );
                lanczos_compress(&k1, rank, &mut rng)
            })
            .collect();
        let mut peak = level.iter().map(|f| f.l.data.len() * 8).sum::<usize>()
            + n * rank * 8 * 2; // Lanczos basis + scratch
        // Merge tree with re-truncation.
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let (Some(a), b) = (it.next(), it.next()) {
                match b {
                    Some(b) => {
                        let op = HadamardOp { a: &a, b: &b };
                        next.push(lanczos_compress(&op, rank, &mut rng));
                    }
                    None => next.push(a),
                }
            }
            level = next;
            peak = peak.max(
                level.iter().map(|f| f.l.data.len() * 8).sum::<usize>()
                    + n * rank * 8 * 2,
            );
        }
        Ok(SkipMvm {
            d,
            n,
            rank,
            outputscale: kernel.outputscale,
            factor: level.pop().unwrap(),
            peak_build_bytes: peak,
        })
    }

    /// Bytes held by the final factor (steady-state memory).
    pub fn storage_bytes(&self) -> usize {
        self.factor.l.data.len() * 8
    }
}

impl MvmOperator for SkipMvm {
    fn len(&self) -> usize {
        self.n
    }
    fn mvm(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.factor.mvm(v);
        if self.outputscale != 1.0 {
            for o in out.iter_mut() {
                *o *= self.outputscale;
            }
        }
        out
    }
}

/// The train-block restriction of a joint (train ∪ test) operator:
/// v_train ↦ (K_joint [v; 0])_train. Sharing one factorization between
/// the solve and the cross-covariance keeps SKIP's low-rank eigenspaces
/// self-consistent.
struct TrainBlock<'a> {
    joint: &'a SkipMvm,
    n: usize,
}

impl MvmOperator for TrainBlock<'_> {
    fn len(&self) -> usize {
        self.n
    }
    fn mvm(&self, v: &[f64]) -> Vec<f64> {
        let mut full = vec![0.0; self.joint.n];
        full[..self.n].copy_from_slice(v);
        let u = self.joint.mvm(&full);
        u[..self.n].to_vec()
    }
}

/// A SKIP-based GP regression model. Both the representer solve and the
/// cross-covariance go through ONE joint (train ∪ test) SKIP operator,
/// matching GPyTorch's joint-kernel evaluation — mixing operators with
/// different low-rank eigenspaces (or exact cross-covariances) amplifies
/// exactly the directions the rank truncation dropped and diverges.
pub struct SkipGp {
    pub kernel: ArdKernel,
    pub noise: f64,
    pub d: usize,
    pub rank: usize,
    pub seed: u64,
    pub cg_tol: f64,
    pub x_train: Vec<f64>,
    pub y_train: Vec<f64>,
}

impl SkipGp {
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        x: &[f64],
        y: &[f64],
        d: usize,
        kernel: ArdKernel,
        noise: f64,
        rank: usize,
        seed: u64,
        cg_tol: f64,
    ) -> Result<Self> {
        ensure!(x.len() == y.len() * d, "shape mismatch");
        ensure!(noise > 0.0, "noise must be positive");
        Ok(SkipGp {
            kernel,
            noise,
            d,
            rank,
            seed,
            cg_tol,
            x_train: x.to_vec(),
            y_train: y.to_vec(),
        })
    }

    fn joint_op(&self, x_star: &[f64]) -> Result<SkipMvm> {
        let mut joint_x = self.x_train.clone();
        joint_x.extend_from_slice(x_star);
        SkipMvm::build(&joint_x, self.d, &self.kernel, self.rank, self.seed)
    }

    /// Predictive mean via the joint operator: solve α against the
    /// train block, push [α; 0] through the joint MVM, read the test
    /// block.
    pub fn predict_mean(&self, x_star: &[f64]) -> Result<Vec<f64>> {
        let n = self.y_train.len();
        let joint = self.joint_op(x_star)?;
        let block = TrainBlock { joint: &joint, n };
        let shifted = crate::mvm::Shifted::new(&block, self.noise);
        let res = crate::solvers::cg(
            &shifted,
            &self.y_train,
            crate::solvers::CgOptions {
                tol: self.cg_tol,
                max_iters: 500,
                min_iters: 1,
            },
        );
        let mut v = vec![0.0; joint.n];
        v[..n].copy_from_slice(&res.x);
        let u = joint.mvm(&v);
        Ok(u[n..].to_vec())
    }

    /// Mean + variance through the same joint operator.
    pub fn predict(&self, x_star: &[f64]) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = self.y_train.len();
        let t = x_star.len() / self.d;
        let joint = self.joint_op(x_star)?;
        let block = TrainBlock { joint: &joint, n };
        let shifted = crate::mvm::Shifted::new(&block, self.noise);
        let res = crate::solvers::cg(
            &shifted,
            &self.y_train,
            crate::solvers::CgOptions {
                tol: self.cg_tol,
                max_iters: 500,
                min_iters: 1,
            },
        );
        let mut v = vec![0.0; joint.n];
        v[..n].copy_from_slice(&res.x);
        let mean = joint.mvm(&v)[n..].to_vec();
        let prior = self.kernel.outputscale + self.noise;
        let mut var = vec![0.0; t];
        for i in 0..t {
            let mut e = vec![0.0; joint.n];
            e[n + i] = 1.0;
            let col = joint.mvm(&e);
            let kstar = &col[..n];
            let sol = crate::solvers::cg(
                &shifted,
                kstar,
                crate::solvers::CgOptions {
                    tol: 1e-2,
                    max_iters: 300,
                    min_iters: 1,
                },
            );
            let quad = crate::util::stats::dot(kstar, &sol.x);
            var[i] = (prior - quad).max(1e-8);
        }
        Ok((mean, var))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelFamily;
    use crate::mvm::ExactMvm;
    use crate::util::stats::cosine_error;

    #[test]
    fn kiss1d_tracks_exact() {
        let n = 120;
        let mut rng = Pcg64::new(1);
        let coords: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let k1 = Kiss1d::build(&coords, |tau| (-0.5 * tau * tau).exp(), 200);
        let v = rng.normal_vec(n);
        let got = k1.mvm(&v);
        // Exact 1-D RBF MVM.
        let mut want = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                let d = coords[i] - coords[j];
                want[i] += (-0.5 * d * d).exp() * v[j];
            }
        }
        let err = cosine_error(&got, &want);
        assert!(err < 1e-3, "kiss1d err {err}");
    }

    #[test]
    fn skip_tracks_exact_rbf() {
        // RBF factors exactly across dimensions, so SKIP at decent rank
        // should track the exact MVM closely.
        let d = 3;
        let n = 150;
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let skip = SkipMvm::build(&x, d, &k, 40, 3).unwrap();
        let exact = ExactMvm::new(&k, &x, d);
        let v = rng.normal_vec(n);
        let err = cosine_error(&skip.mvm(&v), &exact.mvm(&v));
        assert!(err < 0.05, "skip cosine err {err}");
    }

    #[test]
    fn low_rank_hurts() {
        // The paper's observation: SKIP's low-rank truncation can limit
        // accuracy — rank 4 must be worse than rank 40.
        let d = 4;
        let n = 120;
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.6);
        let exact = ExactMvm::new(&k, &x, d);
        let v = rng.normal_vec(n);
        let base = exact.mvm(&v);
        let lo = SkipMvm::build(&x, d, &k, 4, 5).unwrap();
        let hi = SkipMvm::build(&x, d, &k, 40, 5).unwrap();
        let e_lo = cosine_error(&lo.mvm(&v), &base);
        let e_hi = cosine_error(&hi.mvm(&v), &base);
        assert!(e_hi < e_lo, "rank-40 {e_hi} vs rank-4 {e_lo}");
    }

    #[test]
    fn operator_is_symmetric_psd() {
        let d = 2;
        let n = 80;
        let mut rng = Pcg64::new(6);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.7);
        let skip = SkipMvm::build(&x, d, &k, 20, 7).unwrap();
        let u = rng.normal_vec(n);
        let v = rng.normal_vec(n);
        let a = crate::util::stats::dot(&u, &skip.mvm(&v));
        let b = crate::util::stats::dot(&v, &skip.mvm(&u));
        assert!((a - b).abs() < 1e-8 * (1.0 + a.abs()));
        // PSD: vᵀKv >= 0 (factor form guarantees it).
        assert!(crate::util::stats::dot(&v, &skip.mvm(&v)) >= -1e-10);
    }
}

//! Baseline GP methods the paper compares against (Table 2, Figs. 1,
//! 5, 6): Exact GP, SGPR, SKIP and KISS-GP — all built from scratch on
//! the same solver substrate as Simplex-GP.

pub mod exact;
pub mod kissgp;
pub mod sgpr;
pub mod skip;

pub use exact::ExactGp;
pub use kissgp::KissGpMvm;
pub use sgpr::{Sgpr, SgprConfig};
pub use skip::{SkipGp, SkipMvm};

use anyhow::{ensure, Result};

use crate::kernels::ArdKernel;
use crate::mvm::{MvmOperator, Shifted};
use crate::solvers::{cg, CgOptions};

/// Generic iterative GP over any MVM operator (used to run Table 2 with
/// the SKIP operator, and for ablations swapping operators). The
/// predictive mean uses exact cross-covariances (O(t·n·d)), matching
/// how SKIP-based GPyTorch models predict.
pub struct OperatorGp<O: MvmOperator> {
    pub op: O,
    pub kernel: ArdKernel,
    pub noise: f64,
    pub d: usize,
    pub x_train: Vec<f64>,
    pub y_train: Vec<f64>,
    alpha: Vec<f64>,
    pub cg_iterations: usize,
}

impl<O: MvmOperator> OperatorGp<O> {
    pub fn fit(
        op: O,
        x: &[f64],
        y: &[f64],
        d: usize,
        kernel: ArdKernel,
        noise: f64,
        cg_tol: f64,
    ) -> Result<Self> {
        ensure!(op.len() == y.len(), "operator size mismatch");
        ensure!(noise > 0.0, "noise must be positive");
        let shifted = Shifted::new(&op, noise);
        let res = cg(
            &shifted,
            y,
            CgOptions {
                tol: cg_tol,
                max_iters: 500,
                min_iters: 1,
            },
        );
        let alpha = res.x;
        let cg_iterations = res.iterations;
        Ok(OperatorGp {
            op,
            kernel,
            noise,
            d,
            x_train: x.to_vec(),
            y_train: y.to_vec(),
            alpha,
            cg_iterations,
        })
    }

    pub fn predict_mean(&self, x_star: &[f64]) -> Vec<f64> {
        let t = x_star.len() / self.d;
        let n = self.y_train.len();
        let mut out = vec![0.0; t];
        crate::util::parallel::par_fill(&mut out, |range, chunk| {
            for (k, i) in range.enumerate() {
                let xi = &x_star[i * self.d..(i + 1) * self.d];
                let mut acc = 0.0;
                for j in 0..n {
                    acc += self
                        .kernel
                        .eval(xi, &self.x_train[j * self.d..(j + 1) * self.d])
                        * self.alpha[j];
                }
                chunk[k] = acc;
            }
        });
        out
    }

    /// Variance via exact cross-covariance columns + CG on the operator.
    pub fn predict(&self, x_star: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let t = x_star.len() / self.d;
        let n = self.y_train.len();
        let mean = self.predict_mean(x_star);
        let shifted = Shifted::new(&self.op, self.noise);
        let prior = self.kernel.outputscale + self.noise;
        let mut var = vec![0.0; t];
        for i in 0..t {
            let xi = &x_star[i * self.d..(i + 1) * self.d];
            let kstar: Vec<f64> = (0..n)
                .map(|j| {
                    self.kernel
                        .eval(xi, &self.x_train[j * self.d..(j + 1) * self.d])
                })
                .collect();
            let sol = cg(
                &shifted,
                &kstar,
                CgOptions {
                    tol: 1e-2,
                    max_iters: 500,
                    min_iters: 1,
                },
            );
            let quad = crate::util::stats::dot(&kstar, &sol.x);
            var[i] = (prior - quad).max(1e-8);
        }
        (mean, var)
    }

    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelFamily;
    use crate::util::stats::rmse;
    use crate::util::Pcg64;

    #[test]
    fn skip_gp_end_to_end() {
        let d = 3;
        let n = 400;
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let y: Vec<f64> = (0..n)
            .map(|i| (1.2 * x[i * d]).sin() + 0.05 * rng.normal())
            .collect();
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let gp = SkipGp::fit(&x, &y, d, kernel, 0.05, 30, 2, 1e-3).unwrap();
        let xt: Vec<f64> = (0..100 * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let yt: Vec<f64> = (0..100).map(|i| (1.2 * xt[i * d]).sin()).collect();
        let pred = gp.predict_mean(&xt).unwrap();
        let err = rmse(&pred, &yt);
        let base = rmse(&vec![0.0; 100], &yt);
        assert!(err < 0.7 * base, "skip-gp rmse {err} vs {base}");
    }

    #[test]
    fn operator_gp_with_exact_operator_is_consistent() {
        // OperatorGp with the exact operator = a plain exact GP.
        let d = 2;
        let n = 150;
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let y: Vec<f64> = (0..n)
            .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
            .collect();
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let op = crate::mvm::ExactMvm::new(&kernel, &x, d);
        // ExactMvm borrows x/kernel; keep the GP local to this scope.
        let gp = OperatorGp::fit(op, &x, &y, d, kernel.clone(), 0.05, 1e-8).unwrap();
        let pred = gp.predict_mean(&x[..20 * d]);
        let err = rmse(&pred, &y[..20]);
        assert!(err < 0.3, "train-fit rmse {err}");
        let (_, var) = gp.predict(&x[..5 * d]);
        for v in var {
            assert!(v > 0.0 && v < kernel.outputscale + 0.05 + 1e-9);
        }
    }
}

//! KISS-GP (Wilson & Nickisch 2015): structured kernel interpolation on
//! a dense rectilinear grid with Kronecker-of-Toeplitz algebra — the
//! method whose 2^d scaling motivates the paper (Fig. 1, Table 1).
//! Practical only for small d; the Fig. 1 / Table 1 benches use it to
//! exhibit exactly that exponential wall.

use anyhow::{ensure, Result};

use crate::kernels::ArdKernel;
use crate::linalg::{kron_toeplitz_matvec, SymToeplitz};
use crate::mvm::MvmOperator;

/// KISS-GP MVM operator: K ≈ W (T_1 ⊗ … ⊗ T_d) Wᵀ with multilinear
/// interpolation weights (2^d nonzeros per row of W).
pub struct KissGpMvm {
    pub d: usize,
    pub n: usize,
    /// Grid points per dimension.
    pub grid_size: usize,
    /// Per-dimension Toeplitz factors of K_UU.
    factors: Vec<SymToeplitz>,
    /// Interpolation: for each input, 2^d (flat grid index, weight).
    interp_idx: Vec<usize>,
    interp_w: Vec<f64>,
    /// Total grid points m = grid_size^d.
    pub m: usize,
}

impl KissGpMvm {
    /// Build on a regular grid covering the data range per dimension.
    pub fn build(x: &[f64], d: usize, kernel: &ArdKernel, grid_size: usize) -> Result<Self> {
        ensure!(d >= 1 && grid_size >= 2, "bad grid");
        ensure!(x.len() % d == 0, "shape");
        let n = x.len() / d;
        let m = grid_size.pow(d as u32);
        ensure!(
            m <= 1 << 26,
            "grid of {m} points exceeds memory budget (d={d} too high — this is the paper's point)"
        );
        // Per-dim ranges with one-cell padding.
        let mut lo = vec![f64::INFINITY; d];
        let mut hi = vec![f64::NEG_INFINITY; d];
        for i in 0..n {
            for j in 0..d {
                lo[j] = lo[j].min(x[i * d + j]);
                hi[j] = hi[j].max(x[i * d + j]);
            }
        }
        let mut steps = vec![0.0; d];
        for j in 0..d {
            let span = (hi[j] - lo[j]).max(1e-9);
            let step = span / (grid_size as f64 - 1.0);
            lo[j] -= step * 0.5;
            hi[j] += step * 0.5;
            steps[j] = (hi[j] - lo[j]) / (grid_size as f64 - 1.0);
        }
        // Toeplitz factors: 1-D kernel profile along each dimension
        // (RBF and separable kernels factor exactly; others approximately).
        let factors: Vec<SymToeplitz> = (0..d)
            .map(|j| {
                let col: Vec<f64> = (0..grid_size)
                    .map(|t| {
                        let tau = t as f64 * steps[j] / kernel.lengthscales[j];
                        kernel.family.profile(tau * tau)
                    })
                    .collect();
                SymToeplitz::new(col)
            })
            .collect();
        // Multilinear interpolation: 2^d corners per point.
        let corners = 1usize << d;
        let mut interp_idx = vec![0usize; n * corners];
        let mut interp_w = vec![0.0; n * corners];
        for i in 0..n {
            // Per-dim cell + fraction.
            let mut cell = vec![0usize; d];
            let mut frac = vec![0.0; d];
            for j in 0..d {
                let t = ((x[i * d + j] - lo[j]) / steps[j])
                    .clamp(0.0, grid_size as f64 - 1.0 - 1e-9);
                cell[j] = t.floor() as usize;
                frac[j] = t - cell[j] as f64;
            }
            for c in 0..corners {
                let mut flat = 0usize;
                let mut w = 1.0;
                for j in 0..d {
                    let hi_side = (c >> j) & 1 == 1;
                    let idx = cell[j] + usize::from(hi_side);
                    flat = flat * grid_size + idx;
                    w *= if hi_side { frac[j] } else { 1.0 - frac[j] };
                }
                interp_idx[i * corners + c] = flat;
                interp_w[i * corners + c] = w;
            }
        }
        Ok(KissGpMvm {
            d,
            n,
            grid_size,
            factors,
            interp_idx,
            interp_w,
            m,
        })
    }

    /// Grid storage the method requires (Fig. 1 / Fig. 5 accounting).
    pub fn grid_points(&self) -> usize {
        self.m
    }
}

impl MvmOperator for KissGpMvm {
    fn len(&self) -> usize {
        self.n
    }

    fn mvm(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        let corners = 1usize << self.d;
        // Splat onto the grid.
        let mut z = vec![0.0; self.m];
        for i in 0..self.n {
            for c in 0..corners {
                z[self.interp_idx[i * corners + c]] += self.interp_w[i * corners + c] * v[i];
            }
        }
        // Kronecker-Toeplitz MVM.
        let z = kron_toeplitz_matvec(&self.factors, &z);
        // Slice back.
        let mut out = vec![0.0; self.n];
        for i in 0..self.n {
            let mut acc = 0.0;
            for c in 0..corners {
                acc += self.interp_w[i * corners + c] * z[self.interp_idx[i * corners + c]];
            }
            out[i] = acc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelFamily;
    use crate::mvm::ExactMvm;
    use crate::util::stats::cosine_error;
    use crate::util::Pcg64;

    #[test]
    fn tracks_exact_mvm_low_d() {
        let d = 2;
        let n = 150;
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let kiss = KissGpMvm::build(&x, d, &k, 40).unwrap();
        let exact = ExactMvm::new(&k, &x, d);
        let v = rng.normal_vec(n);
        let err = cosine_error(&kiss.mvm(&v), &exact.mvm(&v));
        assert!(err < 0.01, "kiss cosine err {err}");
    }

    #[test]
    fn finer_grid_reduces_error() {
        let d = 2;
        let n = 100;
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
        let exact = ExactMvm::new(&k, &x, d);
        let v = rng.normal_vec(n);
        let base = exact.mvm(&v);
        let coarse = KissGpMvm::build(&x, d, &k, 10).unwrap();
        let fine = KissGpMvm::build(&x, d, &k, 60).unwrap();
        let e_coarse = cosine_error(&coarse.mvm(&v), &base);
        let e_fine = cosine_error(&fine.mvm(&v), &base);
        assert!(e_fine < e_coarse, "fine {e_fine} vs coarse {e_coarse}");
    }

    #[test]
    fn symmetry() {
        let d = 3;
        let n = 60;
        let mut rng = Pcg64::new(3);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.7);
        let kiss = KissGpMvm::build(&x, d, &k, 12).unwrap();
        let u = rng.normal_vec(n);
        let v = rng.normal_vec(n);
        let a = crate::util::stats::dot(&u, &kiss.mvm(&v));
        let b = crate::util::stats::dot(&v, &kiss.mvm(&u));
        assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()));
    }

    #[test]
    fn grid_grows_exponentially() {
        // The Fig. 1 statement in executable form.
        let mut rng = Pcg64::new(4);
        let mut sizes = Vec::new();
        for d in [1usize, 2, 3, 4] {
            let x: Vec<f64> = (0..50 * d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
            let kiss = KissGpMvm::build(&x, d, &k, 10).unwrap();
            sizes.push(kiss.grid_points());
        }
        assert_eq!(sizes, vec![10, 100, 1000, 10000]);
        // And it refuses absurd d.
        let x: Vec<f64> = (0..50 * 12).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, 12, 1.0);
        assert!(KissGpMvm::build(&x, 12, &k, 10).is_err());
    }
}

//! Exact GP baseline: the Table 2 "Exact GP" column and the Fig. 6
//! KeOps-style exact MVM comparator. Solves run CG on the O(n²d)
//! tile-recomputed MVM (no O(n²) storage), preconditioned with partial
//! pivoted Cholesky; small problems may instead use the dense Cholesky
//! path in [`crate::linalg`].

use anyhow::{ensure, Result};

use crate::kernels::ArdKernel;
use crate::mvm::{ExactMvm, Shifted};
use crate::solvers::precond::KernelRows;
use crate::solvers::{cg_precond, CgOptions, PivCholPrecond};

struct Rows<'a> {
    kernel: &'a ArdKernel,
    x: &'a [f64],
    d: usize,
}

impl KernelRows for Rows<'_> {
    fn len(&self) -> usize {
        self.x.len() / self.d
    }
    fn row(&self, i: usize) -> Vec<f64> {
        let xi = &self.x[i * self.d..(i + 1) * self.d];
        (0..self.len())
            .map(|j| self.kernel.eval(xi, &self.x[j * self.d..(j + 1) * self.d]))
            .collect()
    }
    fn diag(&self) -> Vec<f64> {
        vec![self.kernel.outputscale; self.len()]
    }
}

/// A fitted exact GP.
pub struct ExactGp {
    pub kernel: ArdKernel,
    pub noise: f64,
    pub d: usize,
    pub x_train: Vec<f64>,
    pub y_train: Vec<f64>,
    alpha: Vec<f64>,
    pub cg_iterations: usize,
}

impl ExactGp {
    /// Fit with fixed hyperparameters (preconditioned CG, rank per the
    /// paper's Table 5 default of 100, capped by n).
    pub fn fit(
        x: &[f64],
        y: &[f64],
        d: usize,
        kernel: ArdKernel,
        noise: f64,
        cg_tol: f64,
    ) -> Result<Self> {
        ensure!(x.len() % d == 0 && y.len() == x.len() / d, "shape mismatch");
        ensure!(noise > 0.0, "noise must be positive");
        let op = ExactMvm::new(&kernel, x, d);
        let shifted = Shifted::new(&op, noise);
        let rows = Rows {
            kernel: &kernel,
            x,
            d,
        };
        let rank = (y.len() / 2).clamp(1, 100);
        let pc = PivCholPrecond::build(&rows, rank, noise);
        let pcf = |r: &[f64]| pc.solve(r);
        let res = cg_precond(
            &shifted,
            y,
            CgOptions {
                tol: cg_tol,
                max_iters: 1000,
                min_iters: 1,
            },
            Some(&pcf),
        );
        Ok(ExactGp {
            kernel,
            noise,
            d,
            x_train: x.to_vec(),
            y_train: y.to_vec(),
            alpha: res.x,
            cg_iterations: res.iterations,
        })
    }

    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }

    /// Predictive mean: K(X*, X) α, exact cross-covariance.
    pub fn predict_mean(&self, x_star: &[f64]) -> Vec<f64> {
        let t = x_star.len() / self.d;
        let n = self.n_train();
        let mut out = vec![0.0; t];
        crate::util::parallel::par_fill(&mut out, |range, chunk| {
            for (k, i) in range.enumerate() {
                let xi = &x_star[i * self.d..(i + 1) * self.d];
                let mut acc = 0.0;
                for j in 0..n {
                    acc += self
                        .kernel
                        .eval(xi, &self.x_train[j * self.d..(j + 1) * self.d])
                        * self.alpha[j];
                }
                chunk[k] = acc;
            }
        });
        out
    }

    /// Predictive mean + variance. Variance solves are batched through
    /// `cg_multi`: the exact operator's multi-RHS MVM recomputes each
    /// kernel entry once for all channels, so a 64-column batch costs
    /// little more than one solve.
    pub fn predict(&self, x_star: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let t = x_star.len() / self.d;
        let n = self.n_train();
        let mean = self.predict_mean(x_star);
        let op = ExactMvm::new(&self.kernel, &self.x_train, self.d);
        let shifted = Shifted::new(&op, self.noise);
        let prior = self.kernel.outputscale + self.noise;
        let mut var = vec![0.0; t];
        let chunk = 64usize;
        for c0 in (0..t).step_by(chunk) {
            let c1 = (c0 + chunk).min(t);
            let nc = c1 - c0;
            // Interleaved k* columns for the batch.
            let mut cols = vec![0.0; n * nc];
            for (c, i) in (c0..c1).enumerate() {
                let xi = &x_star[i * self.d..(i + 1) * self.d];
                for j in 0..n {
                    cols[j * nc + c] = self
                        .kernel
                        .eval(xi, &self.x_train[j * self.d..(j + 1) * self.d]);
                }
            }
            let (sol, _) = crate::solvers::cg_multi(
                &shifted,
                &cols,
                nc,
                CgOptions {
                    tol: 1e-2,
                    max_iters: 500,
                    min_iters: 1,
                },
            );
            for (c, i) in (c0..c1).enumerate() {
                let mut quad = 0.0;
                for j in 0..n {
                    quad += cols[j * nc + c] * sol[j * nc + c];
                }
                var[i] = (prior - quad).max(1e-8);
            }
        }
        (mean, var)
    }

    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelFamily;
    use crate::linalg::solve_spd;
    use crate::util::Pcg64;

    fn toy(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let y: Vec<f64> = (0..n)
            .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
            .collect();
        (x, y)
    }

    #[test]
    fn matches_dense_cholesky() {
        let d = 2;
        let (x, y) = toy(120, d, 1);
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let noise = 0.1;
        let gp = ExactGp::fit(&x, &y, d, kernel.clone(), noise, 1e-8).unwrap();
        let mut km = kernel.cov_matrix(&x, d);
        km.add_diag(noise);
        let alpha = solve_spd(&km, &y).unwrap();
        for i in 0..y.len() {
            assert!(
                (gp.alpha()[i] - alpha[i]).abs() < 1e-4,
                "alpha {i}: {} vs {}",
                gp.alpha()[i],
                alpha[i]
            );
        }
        // Predictions likewise.
        let (xt, _) = toy(30, d, 2);
        let mean = gp.predict_mean(&xt);
        let kstar = kernel.cross_cov(&xt, &x, d);
        let exact_mean = kstar.matvec(&alpha);
        for i in 0..30 {
            assert!((mean[i] - exact_mean[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn variance_positive_and_bounded() {
        let d = 2;
        let (x, y) = toy(100, d, 3);
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 0.8);
        let gp = ExactGp::fit(&x, &y, d, kernel, 0.05, 1e-6).unwrap();
        let (xt, _) = toy(10, d, 4);
        let (_, var) = gp.predict(&xt);
        let prior = gp.kernel.outputscale + gp.noise;
        for v in var {
            assert!(v > 0.0 && v <= prior + 1e-6);
        }
    }
}

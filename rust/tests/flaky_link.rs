//! TcpTransport reconnect under a flaky link.
//!
//! A byte-pumping TCP proxy sits between the coordinator and a single
//! shard worker holding every shard. On the first proxied connection
//! the proxy waits until the coordinator has sent its first
//! `shard_mvm_block`, forwards only a prefix of the worker's reply —
//! cutting the frame mid-payload — and slams both sockets shut. The
//! contract under test (docs/PROTOCOL.md §Failure semantics):
//!
//!  * the in-flight request still gets exactly one reply, byte-
//!    identical to the direct computation (in-thread fallback);
//!  * the link reconnects through the proxy with backoff, and the
//!    handshake's fingerprint check skips `refresh_shard` because the
//!    worker process kept its replicas warm;
//!  * subsequent jobs flow remotely again — nothing is duplicated,
//!    nothing is lost (`served` matches the request count exactly).

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::util::Pcg64;

fn problem(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

fn fit(x: &[f64], y: &[f64], d: usize, shards: usize) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: row {i} ({} vs {})",
            a[i],
            b[i]
        );
    }
}

fn count_occurrences(hay: &[u8], needle: &[u8]) -> usize {
    if needle.is_empty() || hay.len() < needle.len() {
        return 0;
    }
    hay.windows(needle.len()).filter(|w| *w == needle).count()
}

/// Byte-pumping proxy. Connection 0 is sabotaged: once the
/// coordinator→worker stream contains `shard_mvm_block`, only
/// `CUT_AFTER_BYTES` more worker→coordinator bytes are forwarded
/// before both sockets are shut — a mid-frame cut, since an MVM reply
/// frame is far larger than the budget. Every later connection pipes
/// transparently. Coordinator→worker bytes are recorded per connection
/// so the test can check what the resync actually sent.
struct FlakyProxy {
    pub addr: SocketAddr,
    transcripts: Arc<Mutex<Vec<Vec<u8>>>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

const CUT_AFTER_BYTES: usize = 128;

impl FlakyProxy {
    fn start(worker_addr: SocketAddr) -> FlakyProxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let transcripts: Arc<Mutex<Vec<Vec<u8>>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));

        let t = transcripts.clone();
        let s = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            while !s.load(Ordering::Relaxed) {
                let (client, _) = match listener.accept() {
                    Ok(pair) => pair,
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                        continue;
                    }
                    Err(_) => break,
                };
                let idx = {
                    let mut lock = t.lock().unwrap();
                    lock.push(Vec::new());
                    lock.len() - 1
                };
                Self::pump(client, worker_addr, idx, t.clone());
            }
        });
        FlakyProxy {
            addr,
            transcripts,
            stop,
            accept_thread: Some(accept_thread),
        }
    }

    /// Spawn the two pump threads for one proxied connection. The
    /// threads own their sockets and exit on EOF/error; they are not
    /// joined — closing the sockets is their only teardown.
    fn pump(
        client: TcpStream,
        worker_addr: SocketAddr,
        idx: usize,
        transcripts: Arc<Mutex<Vec<Vec<u8>>>>,
    ) {
        let worker = match TcpStream::connect(worker_addr) {
            Ok(w) => w,
            Err(_) => return, // worker gone; coordinator sees EOF
        };
        client.set_nodelay(true).ok();
        worker.set_nodelay(true).ok();
        let armed = Arc::new(AtomicBool::new(false));

        // coordinator → worker: record, arm the cut *before*
        // forwarding (so the reply can never outrun the trigger), then
        // pass the bytes on.
        {
            let mut from = client.try_clone().unwrap();
            let mut to = worker.try_clone().unwrap();
            let armed = armed.clone();
            std::thread::spawn(move || {
                let mut buf = [0u8; 4096];
                loop {
                    let n = match from.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => n,
                    };
                    let mvm_seen = {
                        let mut lock = transcripts.lock().unwrap();
                        lock[idx].extend_from_slice(&buf[..n]);
                        count_occurrences(&lock[idx], b"shard_mvm_block") > 0
                    };
                    if idx == 0 && mvm_seen {
                        armed.store(true, Ordering::SeqCst);
                    }
                    if to.write_all(&buf[..n]).is_err() {
                        break;
                    }
                }
                to.shutdown(Shutdown::Both).ok();
            });
        }

        // worker → coordinator: transparent, except connection 0 dies
        // CUT_AFTER_BYTES into the first MVM reply.
        {
            let mut from = worker;
            let mut to = client;
            std::thread::spawn(move || {
                let mut budget = CUT_AFTER_BYTES;
                let mut buf = [0u8; 4096];
                loop {
                    let n = match from.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => n,
                    };
                    let cutting = idx == 0 && armed.load(Ordering::SeqCst);
                    let send = if cutting { n.min(budget) } else { n };
                    if to.write_all(&buf[..send]).is_err() {
                        break;
                    }
                    if cutting {
                        budget -= send;
                        if budget == 0 {
                            // Mid-frame cut: both directions, hard.
                            to.shutdown(Shutdown::Both).ok();
                            from.shutdown(Shutdown::Both).ok();
                            break;
                        }
                    }
                }
                to.shutdown(Shutdown::Both).ok();
                from.shutdown(Shutdown::Both).ok();
            });
        }
    }

    fn connections(&self) -> usize {
        self.transcripts.lock().unwrap().len()
    }

    fn occurrences_on(&self, conn: usize, needle: &str) -> usize {
        let lock = self.transcripts.lock().unwrap();
        lock.get(conn)
            .map_or(0, |t| count_occurrences(t, needle.as_bytes()))
    }

    fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            t.join().ok();
        }
    }
}

/// Block until `stats.remote_workers == want` (resync runs in the
/// background; reconnect backoff starts at 50 ms and doubles).
fn wait_remote_workers(client: &mut Client, want: usize, what: &str) {
    let t0 = Instant::now();
    loop {
        let got = client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0) as i64;
        if got == want as i64 {
            return;
        }
        assert!(
            t0.elapsed().as_secs() < 20,
            "{what}: remote_workers stuck at {got} (want {want})"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn mid_frame_cut_reconnects_with_fingerprint_skip_and_no_lost_jobs() {
    let d = 2;
    let shards = 2;
    let (x, y) = problem(240, d, 61);
    let reference = fit(&x, &y, d, shards);
    let n = reference.n_train();

    // One worker holds both shards; the coordinator only knows the
    // proxy's address.
    let worker = ShardWorker::start(WorkerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..WorkerConfig::default()
    })
    .unwrap();
    let proxy = FlakyProxy::start(worker.local_addr);
    let server = Server::start(
        fit(&x, &y, d, shards),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            cluster: ClusterConfig {
                workers: vec![proxy.addr.to_string()],
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_workers(&mut client, 1, "initial sync");
    assert_eq!(worker.held_shards(), vec![0, 1]);

    // Request 1 rides connection 0 and triggers the mid-frame cut. The
    // coordinator must still answer — once, byte-identically — via the
    // in-thread fallback.
    let mut rng = Pcg64::new(600);
    let mut requests = 0u64;
    let v = rng.normal_vec(n);
    let direct = reference.operator().lattice.mvm(&v);
    let got = client.mvm(&v).unwrap();
    requests += 1;
    assert_bits_eq(&got, &direct, "mvm during cut");

    // The link reconnects through the proxy; the worker process never
    // died, so the hello fingerprints match and resync is a no-op.
    wait_remote_workers(&mut client, 1, "reconnect");
    assert!(
        proxy.connections() >= 2,
        "no reconnect: {} proxied connections",
        proxy.connections()
    );
    assert!(
        proxy.occurrences_on(0, "refresh_shard") >= 1,
        "connection 0 never synced replicas"
    );
    assert!(
        proxy.occurrences_on(1, "hello") >= 1,
        "connection 1 carried no handshake"
    );
    assert_eq!(
        proxy.occurrences_on(1, "refresh_shard"),
        0,
        "fingerprint skip failed: reconnect re-sent replicas"
    );

    // Traffic flows remotely again on connection 1: every later reply
    // is byte-identical and the worker's serve counter advances by
    // `shards` per request (no fallback, no duplicate shard jobs).
    let served_before = worker.served();
    const AFTER: u64 = 4;
    for i in 0..AFTER {
        let v = rng.normal_vec(n);
        let direct = reference.operator().lattice.mvm(&v);
        let got = client.mvm(&v).unwrap();
        requests += 1;
        assert_bits_eq(&got, &direct, &format!("post-reconnect mvm {i}"));
    }
    let served_after = worker.served();
    assert!(
        served_after >= served_before + AFTER * shards as u64,
        "post-reconnect jobs did not run remotely \
         ({served_before} -> {served_after})"
    );

    // Exactly one reply per request: the serial client saw `requests`
    // replies, and the server counted the same — nothing duplicated,
    // nothing lost, batcher alive.
    let stats = client.stats().unwrap();
    let served = stats.get("served").and_then(|s| s.as_f64()).unwrap();
    assert_eq!(served, requests as f64, "request/reply count mismatch");
    assert_eq!(stats.get("shards").and_then(|s| s.as_f64()), Some(2.0));

    server.shutdown();
    proxy.shutdown();
    worker.shutdown();
}

#[test]
fn proxy_cut_does_not_wipe_worker_replicas() {
    // Companion check for the fingerprint-skip assertion above: the
    // worker keeps replicas across connection loss, so a reconnect has
    // something to skip *to*. Drives the worker through the proxy,
    // cuts, and inspects the worker directly.
    let d = 2;
    let (x, y) = problem(220, d, 62);
    let worker = ShardWorker::start(WorkerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..WorkerConfig::default()
    })
    .unwrap();
    let proxy = FlakyProxy::start(worker.local_addr);
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            cluster: ClusterConfig {
                workers: vec![proxy.addr.to_string()],
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_workers(&mut client, 1, "initial sync");
    let fp_before = worker.held_shards();

    let mut rng = Pcg64::new(620);
    let n = 220;
    let v = rng.normal_vec(n);
    client.mvm(&v).unwrap(); // triggers the cut
    wait_remote_workers(&mut client, 1, "reconnect");

    assert_eq!(worker.held_shards(), fp_before, "replicas dropped");
    server.shutdown();
    proxy.shutdown();
    worker.shutdown();
}

//! Equivalence guarantees of the sharded data-parallel lattice engine
//! (ARCHITECTURE.md §Sharding):
//!
//! - P = 1 must reproduce the single-lattice operator to ≤ 1e-10 (it is
//!   in fact bitwise identical — one shard runs the same arithmetic).
//! - P > 1 has *exact partitioned semantics*: shard p's output rows
//!   equal a standalone lattice built on shard p's points, for both the
//!   single-RHS and the `b × n` block paths, across d ∈ {2, 5, 8} and
//!   P ∈ {1, 2, 4}.
//! - Block-CG on the sharded operator converges each RHS exactly as
//!   sequential CG does, and (P > 1) the converged solution equals the
//!   concatenation of independent per-shard solves — CG on a
//!   block-diagonal operator cannot mix shards.
//! - The serving coordinator's shard workers return byte-identical
//!   replies to the direct in-process path (float bits survive the JSON
//!   round trip: shortest round-trip formatting on the way out, exact
//!   parse on the way in).

use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::{PermutohedralLattice, ShardedLattice};
use simplex_gp::mvm::{MvmOperator, ShardedMvm, Shifted, SimplexMvm};
use simplex_gp::solvers::{cg, cg_block, CgOptions};
use simplex_gp::util::stats::rmse;
use simplex_gp::util::Pcg64;

const DIMS: [usize; 3] = [2, 5, 8];
const SHARDS: [usize; 3] = [1, 2, 4];

fn random_points(n: usize, d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::with_stream(0x5aa2_d011, seed);
    rng.normal_vec(n * d)
}

#[test]
fn p1_matches_single_lattice_across_dims() {
    // The acceptance bound: sharded vs single-lattice agreement ≤ 1e-10
    // for P = 1, on both the raw lattice surface and the operator.
    for (case, &d) in DIMS.iter().enumerate() {
        let n = 120;
        let x = random_points(n, d, case as u64);
        let mut k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.9);
        k.outputscale = 1.3;
        let mut rng = Pcg64::new(40 + case as u64);
        let v = rng.normal_vec(n);
        let b = 3;
        let vb = rng.normal_vec(n * b);
        for symmetrize in [false, true] {
            let single = SimplexMvm::build(&x, d, &k, 1).with_symmetrize(symmetrize);
            let sharded = ShardedMvm::build(&x, d, &k, 1, 1).with_symmetrize(symmetrize);
            let (a, bb) = (sharded.mvm(&v), single.mvm(&v));
            for i in 0..n {
                assert!(
                    (a[i] - bb[i]).abs() <= 1e-10,
                    "d={d} sym={symmetrize} row {i}: {} vs {}",
                    a[i],
                    bb[i]
                );
            }
            let (ab, sb) = (sharded.mvm_block(&vb, b), single.mvm_block(&vb, b));
            for i in 0..n * b {
                assert!(
                    (ab[i] - sb[i]).abs() <= 1e-10,
                    "d={d} sym={symmetrize} block idx {i}"
                );
            }
        }
    }
}

#[test]
fn partitioned_semantics_across_dims_and_shards() {
    // Exact partitioned semantics for every (d, P): shard p's rows of
    // the sharded MVM equal a standalone lattice built on shard p's
    // points, and the block path matches the single-RHS path per RHS.
    for &d in &DIMS {
        let n = 96;
        let x = random_points(n, d, 100 + d as u64);
        let k = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 0.8);
        let mut rng = Pcg64::new(200 + d as u64);
        let v = rng.normal_vec(n);
        for &p in &SHARDS {
            let sharded = ShardedLattice::build(&x, d, &k, 1, p);
            assert_eq!(sharded.shard_count(), p);
            let u = sharded.mvm(&v);
            for s in 0..p {
                let r = sharded.shard_range(s);
                let solo = PermutohedralLattice::build(&x[r.start * d..r.end * d], d, &k, 1);
                let us = solo.mvm(&v[r.clone()]);
                for (i, (got, want)) in u[r].iter().zip(&us).enumerate() {
                    assert!(
                        (got - want).abs() < 1e-12,
                        "d={d} P={p} shard {s} row {i}: {got} vs {want}"
                    );
                }
            }
            let b = 4;
            let vb = rng.normal_vec(n * b);
            let block = sharded.mvm_block(&vb, b);
            for c in 0..b {
                let single = sharded.mvm(&vb[c * n..(c + 1) * n]);
                for i in 0..n {
                    assert!(
                        (block[c * n + i] - single[i]).abs() < 1e-12,
                        "d={d} P={p} rhs {c} row {i}"
                    );
                }
            }
        }
    }
}

#[test]
fn block_cg_on_sharded_operator_matches_sequential() {
    // The production solve shape: (symmetrized sharded lattice + σ²I)
    // block-solved must freeze each RHS at exactly the sequential
    // iteration count with the same iterates, for every shard count.
    let d = 3;
    let n = 150;
    let noise = 0.2;
    let x = random_points(n, d, 7);
    let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let mut rng = Pcg64::new(8);
    let b = 3;
    let rhs = rng.normal_vec(n * b);
    let opts = CgOptions {
        tol: 1e-8,
        max_iters: 500,
        min_iters: 1,
    };
    for &p in &SHARDS {
        let op = ShardedMvm::build(&x, d, &k, 1, p).with_symmetrize(true);
        let shifted = Shifted::new(&op, noise);
        let res = cg_block(&shifted, &rhs, b, opts);
        for c in 0..b {
            let single = cg(&shifted, &rhs[c * n..(c + 1) * n], opts);
            assert_eq!(
                res.rhs_iterations[c], single.iterations,
                "P={p} rhs {c} iterations"
            );
            for i in 0..n {
                assert!(
                    (res.x[c * n + i] - single.x[i]).abs() <= 1e-10 * (1.0 + single.x[i].abs()),
                    "P={p} rhs {c} row {i}"
                );
            }
        }
    }
}

#[test]
fn sharded_solve_equals_independent_shard_solves() {
    // CG on the block-diagonal sharded operator cannot mix shards: the
    // converged solution restricted to shard p equals an independent
    // solve of shard p's own system (a standalone lattice on its
    // points). This is the solver-level witness of the partitioned
    // semantics.
    let d = 2;
    let n = 140;
    let noise = 0.3;
    let x = random_points(n, d, 9);
    let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.7);
    let mut rng = Pcg64::new(10);
    let y = rng.normal_vec(n);
    let opts = CgOptions {
        tol: 1e-10,
        max_iters: 800,
        min_iters: 1,
    };
    let p = 2;
    let sharded = ShardedMvm::build(&x, d, &k, 1, p).with_symmetrize(true);
    let shifted = Shifted::new(&sharded, noise);
    let full = cg(&shifted, &y, opts);
    assert!(full.converged, "full solve rms={}", full.rms_residual);
    for s in 0..p {
        let r = sharded.lattice.shard_range(s);
        let solo =
            SimplexMvm::build(&x[r.start * d..r.end * d], d, &k, 1).with_symmetrize(true);
        let solo_shifted = Shifted::new(&solo, noise);
        let part = cg(&solo_shifted, &y[r.clone()], opts);
        for (i, (got, want)) in full.x[r].iter().zip(&part.x).enumerate() {
            assert!(
                (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                "shard {s} row {i}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn sharded_model_tracks_unsharded_predictions() {
    // End-to-end semantics of the committee-mean reduction: a P = 2
    // model must predict close to the P = 1 model on a smooth target
    // (both are consistent estimators of the same function) and both
    // must beat the trivial predictor.
    let d = 2;
    let n = 400;
    let mut rng = Pcg64::new(11);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (1.3 * x[i * d]).sin() + (1.3 * x[i * d + 1]).sin() + 0.05 * rng.normal())
        .collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let gp1 = SimplexGp::fit(&x, &y, d, kernel.clone(), 0.05, GpConfig::default()).unwrap();
    let cfg2 = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    let gp2 = SimplexGp::fit(&x, &y, d, kernel, 0.05, cfg2).unwrap();
    assert_eq!(gp1.shards(), 1);
    assert_eq!(gp2.shards(), 2);
    let xt: Vec<f64> = (0..100 * d).map(|_| rng.uniform_in(-1.8, 1.8)).collect();
    let yt: Vec<f64> = (0..100)
        .map(|i| (1.3 * xt[i * d]).sin() + (1.3 * xt[i * d + 1]).sin())
        .collect();
    let p1 = gp1.predict_mean(&xt);
    let p2 = gp2.predict_mean(&xt);
    let base = rmse(&vec![0.0; 100], &yt);
    assert!(rmse(&p1, &yt) < 0.5 * base, "unsharded model underfits");
    assert!(rmse(&p2, &yt) < 0.5 * base, "sharded model underfits");
    let cos = simplex_gp::util::stats::cosine_error(&p1, &p2);
    assert!(cos < 0.1, "sharded vs unsharded prediction cosine error {cos}");
    // Variance machinery stays sane under sharding.
    let (_, var) = gp2.predict(&xt[..10 * d]);
    let prior = gp2.kernel.outputscale + gp2.noise;
    for v in var {
        assert!(v > 0.0 && v <= prior + 1e-6, "variance {v} out of range");
    }
}

#[test]
fn coordinator_shard_workers_byte_identical_to_direct() {
    // Concurrent clients against a sharded model must receive replies
    // whose floats are bit-for-bit the direct in-process sharded MVM —
    // the channel hop through the shard workers adds no numeric drift.
    let d = 2;
    let n = 200;
    let mut rng = Pcg64::new(21);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n).map(|i| (x[i * d]).sin() + 0.05 * rng.normal()).collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    let model = SimplexGp::fit(&x, &y, d, kernel, 0.05, cfg).unwrap();
    assert_eq!(model.shards(), 2);
    let v = rng.normal_vec(n);
    let direct = model.operator().lattice.mvm(&v);
    let serve_cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_wait: std::time::Duration::from_millis(150),
        ..ServeConfig::default()
    };
    let server = Server::start(model, serve_cfg).unwrap();
    let addr = server.local_addr;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(4));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let v = v.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                barrier.wait();
                c.mvm(&v).unwrap()
            })
        })
        .collect();
    for h in handles {
        let u = h.join().unwrap();
        assert_eq!(u.len(), n);
        for i in 0..n {
            assert_eq!(
                u[i].to_bits(),
                direct[i].to_bits(),
                "row {i}: served {} != direct {} (bitwise)",
                u[i],
                direct[i]
            );
        }
    }
    // Stats report the shard count.
    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("shards").and_then(|s| s.as_f64()), Some(2.0));
    server.shutdown();
}

#[test]
fn coordinator_p1_byte_identical_to_raw_single_lattice() {
    // With P = 1 the whole stack — model fit, shard worker, reply
    // serialization — must reproduce the raw single-lattice MVM bit for
    // bit: the unsharded PR-1 path is the P = 1 special case.
    let d = 2;
    let n = 150;
    let mut rng = Pcg64::new(31);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n).map(|i| (x[i * d]).cos() + 0.05 * rng.normal()).collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let model = SimplexGp::fit(&x, &y, d, kernel.clone(), 0.05, GpConfig::default()).unwrap();
    let raw = PermutohedralLattice::build(&x, d, &kernel, 1);
    let v = rng.normal_vec(n);
    let want = raw.mvm(&v);
    let serve_cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        ..ServeConfig::default()
    };
    let server = Server::start(model, serve_cfg).unwrap();
    let mut c = Client::connect(&server.local_addr).unwrap();
    let got = c.mvm(&v).unwrap();
    for i in 0..n {
        assert_eq!(got[i].to_bits(), want[i].to_bits(), "row {i}");
    }
    server.shutdown();
}

//! Shed-mode equivalence + fault-injection suite (PR 8).
//!
//! Pins the fully worker-resident serving contract: a coordinator
//! running `[cluster] shed_shards` against healthy workers serves the
//! ENTIRE op mix — predict-with-variance, raw mvm, small incremental
//! ingest, and oversized refit ingest — without ever materializing a
//! local shard lattice (`shed_rebuilds == 0`), and every reply is
//! byte-identical (float bits through the JSON wire) to both an
//! unshed remote-pool server and a direct in-process twin model.
//!
//! The fault legs then break the cluster mid-stream with the
//! deterministic debug ops (`debug_delay_worker` mid-variance,
//! `debug_kill_worker` mid-ingest) and assert the degraded path:
//! exactly one reply per request, still byte-identical, produced by
//! the counted on-demand rebuild fallback — and, once the link
//! recovers, the rebuilt shards are shed again.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::util::Pcg64;

fn problem(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

fn fit(x: &[f64], y: &[f64], d: usize, shards: usize) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
}

fn start_workers(count: usize) -> Vec<ShardWorker> {
    (0..count)
        .map(|_| {
            ShardWorker::start(WorkerConfig {
                listen: "127.0.0.1:0".to_string(),
                ..WorkerConfig::default()
            })
            .unwrap()
        })
        .collect()
}

fn cluster_cfg(workers: &[ShardWorker], shed: bool) -> ClusterConfig {
    ClusterConfig {
        workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
        shed_shards: shed,
        ..ClusterConfig::default()
    }
}

fn wait_remote_synced(client: &mut Client, want: usize) {
    let t0 = Instant::now();
    loop {
        let got = client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0) as i64;
        if got == want as i64 {
            return;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "remote workers never synced: {got}/{want}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn shed_count(client: &mut Client) -> usize {
    client
        .stats()
        .unwrap()
        .get("shed_shards")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as usize
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: row {i} ({} vs {})",
            a[i],
            b[i]
        );
    }
}

/// Fire one raw debug op at the coordinator (the ops are JSON-lines,
/// gated by `debug_ops`) and return the reply line.
fn debug_op(addr: &std::net::SocketAddr, line: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply
}

/// The headline equivalence pin: at P ∈ {2, 3}, the full op mix through
/// a shed coordinator is byte-identical to an unshed remote-pool server
/// AND to a direct twin model mutated in lockstep — with zero on-demand
/// rebuilds and the shards still (re-)shed at every step.
#[test]
fn full_op_mix_shed_equals_unshed_and_direct_byte_identical() {
    let d = 2;
    let max_ingest_batch = 16;
    for shards in [2usize, 3] {
        let (x, y) = problem(240, d, 61 + shards as u64);
        let mut twin = fit(&x, &y, d, shards);

        let unshed_workers = start_workers(2);
        let shed_workers = start_workers(2);
        let mk_cfg = |cluster: ClusterConfig| ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            max_ingest_batch,
            cluster,
            ..ServeConfig::default()
        };
        let unshed_server = Server::start(
            fit(&x, &y, d, shards),
            mk_cfg(cluster_cfg(&unshed_workers, false)),
        )
        .unwrap();
        let shed_server = Server::start(
            fit(&x, &y, d, shards),
            mk_cfg(cluster_cfg(&shed_workers, true)),
        )
        .unwrap();
        let mut unshed = Client::connect(&unshed_server.local_addr).unwrap();
        let mut shed = Client::connect(&shed_server.local_addr).unwrap();
        wait_remote_synced(&mut unshed, 2);
        wait_remote_synced(&mut shed, 2);
        assert_eq!(shed_count(&mut shed), shards, "P={shards}: not shed at start");

        let mut rng = Pcg64::new(700 + shards as u64);
        let check_round = |twin: &SimplexGp,
                               unshed: &mut Client,
                               shed: &mut Client,
                               rng: &mut Pcg64,
                               tag: &str| {
            // Predict with variance.
            let t = 3;
            let xq: Vec<f64> = (0..t * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
            let (dm, dv) = twin.predict(&xq);
            let (um, uv) = unshed.predict_var(&xq, d).unwrap();
            let (sm, sv) = shed.predict_var(&xq, d).unwrap();
            assert_bits_eq(&um, &dm, &format!("P={shards} {tag} unshed mean"));
            assert_bits_eq(&uv, &dv, &format!("P={shards} {tag} unshed var"));
            assert_bits_eq(&sm, &dm, &format!("P={shards} {tag} shed mean"));
            assert_bits_eq(&sv, &dv, &format!("P={shards} {tag} shed var"));
            assert!(sv.iter().all(|&v| v > 0.0), "P={shards} {tag}: var <= 0");
            // Raw MVM.
            let v = rng.normal_vec(twin.n_train());
            let direct = twin.operator().lattice.mvm(&v);
            assert_bits_eq(
                &unshed.mvm(&v).unwrap(),
                &direct,
                &format!("P={shards} {tag} unshed mvm"),
            );
            assert_bits_eq(
                &shed.mvm(&v).unwrap(),
                &direct,
                &format!("P={shards} {tag} shed mvm"),
            );
        };

        check_round(&twin, &mut unshed, &mut shed, &mut rng, "initial");

        // Small ingest: under the cap, absorbed incrementally — on the
        // shed server by patching the owning worker's replica in place
        // (the coordinator updates points + fingerprint metadata only).
        let rows = 6;
        let (xi, yi) = problem(rows, d, 900 + shards as u64);
        let n_unshed = unshed.ingest(&xi, &yi, d).unwrap();
        let n_shed = shed.ingest(&xi, &yi, d).unwrap();
        twin.ingest(&xi, &yi).unwrap();
        assert_eq!(n_unshed, twin.n_train(), "P={shards}: unshed ingest n");
        assert_eq!(n_shed, twin.n_train(), "P={shards}: shed ingest n");
        assert_eq!(
            shed_count(&mut shed),
            shards,
            "P={shards}: small ingest materialized a shard"
        );
        check_round(&twin, &mut unshed, &mut shed, &mut rng, "post-ingest");

        // Oversized ingest: over the cap, a full refit. The shed server
        // rebuilds shard-by-shard with every lattice shed at birth and
        // re-solves α on the routed operator; the refit appends the
        // batch at the end of the training set, so the twin mirror is a
        // fit of the concatenated data — warm-seeded with the pre-refit
        // α zero-extended over the appended rows, exactly as the
        // coordinator seeds its refit (PR 9 warm restarts).
        let rows = max_ingest_batch + 8;
        let (xi, yi) = problem(rows, d, 1100 + shards as u64);
        let n_unshed = unshed.ingest(&xi, &yi, d).unwrap();
        let n_shed = shed.ingest(&xi, &yi, d).unwrap();
        let mut xs = twin.x_train.clone();
        xs.extend_from_slice(&xi);
        let mut ys = twin.y_train.clone();
        ys.extend_from_slice(&yi);
        let mut seed = twin.alpha().to_vec();
        seed.resize(ys.len(), 0.0);
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
        let cfg = GpConfig {
            shards,
            ..GpConfig::default()
        };
        twin = SimplexGp::fit_seeded(&xs, &ys, d, kernel, 0.05, cfg, Some(&seed)).unwrap();
        assert_eq!(n_unshed, twin.n_train(), "P={shards}: unshed refit n");
        assert_eq!(n_shed, twin.n_train(), "P={shards}: shed refit n");
        assert_eq!(
            shed_count(&mut shed),
            shards,
            "P={shards}: refit left shards resident"
        );
        check_round(&twin, &mut unshed, &mut shed, &mut rng, "post-refit");

        // Healthy cluster: the shed coordinator never had to
        // materialize a shard lattice, and the variance really was
        // served off the worker replicas.
        assert_eq!(
            shed_server.shed_rebuilds(),
            0,
            "P={shards}: healthy cluster forced a rebuild"
        );
        let varianced: u64 = shed_workers.iter().map(|w| w.varianced()).sum();
        assert!(
            varianced as usize >= 3 * shards,
            "P={shards}: only {varianced} remote variance jobs served"
        );

        shed_server.shutdown();
        unshed_server.shutdown();
        for w in unshed_workers.into_iter().chain(shed_workers) {
            w.shutdown();
        }
    }
}

/// Mid-variance fault: delay the worker past the result deadline, so a
/// predict-with-variance on a fully shed coordinator must fall back to
/// the deterministic in-thread rebuild. The reply stays byte-identical,
/// `shed_rebuilds` counts the rebuilt shards, and once the delay is
/// lifted the rebuilt shards are shed again — after which variance
/// serves remotely once more without further rebuilds.
#[test]
fn delayed_worker_mid_variance_falls_back_byte_identical_then_resheds() {
    let d = 2;
    let shards = 2;
    let (x, y) = problem(230, d, 71);
    let twin = fit(&x, &y, d, shards);

    let workers = start_workers(2);
    let mut cluster = cluster_cfg(&workers, true);
    cluster.result_timeout = Duration::from_millis(250);
    let server = Server::start(
        fit(&x, &y, d, shards),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);
    assert_eq!(shed_count(&mut client), shards);

    let t = 3;
    let mut rng = Pcg64::new(810);
    let xq: Vec<f64> = (0..t * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let (dm, dv) = twin.predict(&xq);

    // Healthy: remote variance, no rebuilds.
    let (m0, v0) = client.predict_var(&xq, d).unwrap();
    assert_bits_eq(&m0, &dm, "healthy mean");
    assert_bits_eq(&v0, &dv, "healthy var");
    assert_eq!(server.shed_rebuilds(), 0);

    // Inject a delay past the result deadline on shard 0's worker:
    // the in-flight variance job cannot answer in time.
    let reply = debug_op(
        &server.local_addr,
        "{\"id\":50,\"op\":\"debug_delay_worker\",\"shard\":0,\"delay_ms\":1500}",
    );
    assert!(reply.contains("\"delayed\":1"), "got: {reply}");

    // Exactly one reply, still byte-identical — via the rebuild
    // fallback, which counts every shed shard it materialized.
    let (m1, v1) = client.predict_var(&xq, d).unwrap();
    assert_bits_eq(&m1, &dm, "mid-fault mean");
    assert_bits_eq(&v1, &dv, "mid-fault var");
    assert!(
        server.shed_rebuilds() >= 1,
        "fallback did not count a rebuild"
    );

    // Lift the delay; the batcher re-sheds rebuilt shards once their
    // links are ready again (checked per batch iteration, so keep ops
    // flowing while polling). The link must also drain any jobs queued
    // behind the injected delay, so settle on the first round where the
    // shards are shed AND an mvm rode the remote path without forcing
    // a new rebuild.
    let reply = debug_op(
        &server.local_addr,
        "{\"id\":51,\"op\":\"debug_delay_worker\",\"shard\":0,\"delay_ms\":0}",
    );
    assert!(reply.contains("\"delayed\":1"), "got: {reply}");
    let n = twin.n_train();
    let v = rng.normal_vec(n);
    let direct = twin.operator().lattice.mvm(&v);
    let t0 = Instant::now();
    loop {
        let before = server.shed_rebuilds();
        let u = client.mvm(&v).unwrap();
        assert_bits_eq(&u, &direct, "post-recovery mvm");
        if shed_count(&mut client) == shards && server.shed_rebuilds() == before {
            break;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "rebuilt shards never re-shed after link recovery"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // Re-shed and healthy again: variance serves remotely, byte-
    // identical, without growing the rebuild count.
    let rebuilds_settled = server.shed_rebuilds();
    let (m2, v2) = client.predict_var(&xq, d).unwrap();
    assert_bits_eq(&m2, &dm, "post-recovery mean");
    assert_bits_eq(&v2, &dv, "post-recovery var");
    assert_eq!(
        server.shed_rebuilds(),
        rebuilds_settled,
        "recovered cluster kept rebuilding"
    );
    assert_eq!(shed_count(&mut client), shards, "variance forced a re-materialize");

    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Mid-ingest fault: kill every worker link, then ingest into the
/// fully shed coordinator. The synchronous replica patch cannot land,
/// so the coordinator desyncs the target, rebuilds in-thread (counted),
/// patches locally, and solves α locally — one reply, byte-identical to
/// the twin, and the whole op mix keeps serving off the fallback.
#[test]
fn killed_worker_mid_ingest_falls_back_byte_identical() {
    let d = 2;
    let shards = 2;
    let (x, y) = problem(220, d, 81);
    let mut twin = fit(&x, &y, d, shards);

    let workers = start_workers(2);
    let mut cluster = cluster_cfg(&workers, true);
    cluster.result_timeout = Duration::from_millis(250);
    let server = Server::start(
        fit(&x, &y, d, shards),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            max_ingest_batch: 16,
            debug_ops: true,
            cluster,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);
    assert_eq!(shed_count(&mut client), shards);

    // Kill the links serving both shards: whichever shard the ingest
    // targets, its replica patch must fail.
    for p in 0..shards {
        let reply = debug_op(
            &server.local_addr,
            &format!("{{\"id\":60,\"op\":\"debug_kill_worker\",\"shard\":{p}}}"),
        );
        assert!(reply.contains("\"killed\":1"), "got: {reply}");
    }

    // The ingest still gets exactly one reply and both models agree.
    let rows = 6;
    let (xi, yi) = problem(rows, d, 910);
    let n_live = client.ingest(&xi, &yi, d).unwrap();
    twin.ingest(&xi, &yi).unwrap();
    assert_eq!(n_live, twin.n_train(), "mid-fault ingest diverged");
    assert!(
        server.shed_rebuilds() >= 1,
        "ingest fallback did not count a rebuild"
    );

    // The degraded coordinator still answers the rest of the mix
    // byte-identically (everything in-thread now).
    let mut rng = Pcg64::new(820);
    let v = rng.normal_vec(twin.n_train());
    let direct = twin.operator().lattice.mvm(&v);
    assert_bits_eq(&client.mvm(&v).unwrap(), &direct, "post-fault mvm");
    let t = 2;
    let xq: Vec<f64> = (0..t * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let (dm, dv) = twin.predict(&xq);
    let (sm, sv) = client.predict_var(&xq, d).unwrap();
    assert_bits_eq(&sm, &dm, "post-fault mean");
    assert_bits_eq(&sv, &dv, "post-fault var");

    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

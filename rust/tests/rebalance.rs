//! Background shard-rebalancing suite (PR 9).
//!
//! Pins the `[cluster] rebalance_skew` contract: when lightest-first
//! ingest routing lets per-shard lattice sizes skew past the threshold,
//! the coordinator rebuilds the (heaviest, lightest) pair on a
//! background thread and swaps it in atomically — and until that swap,
//! every reply is byte-identical to a never-rebalancing twin. After the
//! swap, every reply is byte-identical to a twin that ran the same
//! deterministic rebalance ([`SimplexGp::rebalance_pair`]) — there is
//! no in-between state a client can observe.
//!
//! The fault leg kills the heavy shard's worker link first
//! (`debug_kill_worker`) and drives the same skew: the rebalance must
//! go through against the degraded pool (byte-identical throughout),
//! after which the surviving link re-syncs its swapped replica and
//! serves it remotely again.
//!
//! The stats legs pin `rebalances` / `warm_iters` / `cold_iters`
//! coherence, including the rebalance-off default (`rebalance_skew =
//! 0`), which must never count a rebalance.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::util::Pcg64;

const D: usize = 2;

/// Deterministic base problem: uniform points, so the two shards start
/// with comparable lattice sizes (skew ≈ 1).
fn problem(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * D).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * D]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

fn fit(x: &[f64], y: &[f64]) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, D, 0.5);
    let cfg = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, D, kernel, 0.05, cfg).unwrap()
}

/// One skew-driving ingest batch. Even steps are spread far out
/// (uniform in ±8 — mostly fresh lattice keys, so the receiving
/// shard's m jumps); odd steps are a tight cluster (±0.1 — few fresh
/// keys). Lightest-first routing with the lowest-index tie-break
/// alternates equal-sized batches between the two shards, so the
/// spread batches keep landing on shard 0 and its lattice outgrows
/// shard 1's.
fn skew_batch(step: usize, rows: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::with_stream(0x5e1f, step as u64);
    let scale = if step % 2 == 0 { 8.0 } else { 0.1 };
    let x: Vec<f64> = (0..rows * D)
        .map(|_| rng.uniform_in(-scale, scale))
        .collect();
    let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    (x, y)
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: row {i} ({} vs {})",
            a[i],
            b[i]
        );
    }
}

fn stat_f64(client: &mut Client, key: &str) -> f64 {
    client
        .stats()
        .unwrap()
        .get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("stats op missing '{key}'"))
}

/// Fire one raw debug op at the coordinator and return the reply line.
fn debug_op(addr: &std::net::SocketAddr, line: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply
}

/// Drive skewed ingests through `client` and `twin` in lockstep until
/// the twin's skew crosses `threshold` (checked after EVERY batch, so
/// the server cannot cross — and launch a background build — anywhere
/// but at the final state). Returns the recorded batches for replay.
fn drive_skew(
    client: &mut Client,
    twin: &mut SimplexGp,
    threshold: f64,
) -> Vec<(Vec<f64>, Vec<f64>)> {
    let mut batches = Vec::new();
    for step in 0..80 {
        let (xb, yb) = skew_batch(step, 5);
        let n_live = client.ingest(&xb, &yb, D).unwrap();
        twin.ingest(&xb, &yb).unwrap();
        assert_eq!(n_live, twin.n_train(), "step {step}: ingest diverged");
        batches.push((xb, yb));
        let (_, _, skew) = twin.skew_pair().expect("P=2 always has a pair");
        if skew > threshold {
            return batches;
        }
        // Still below the threshold, so no build can have launched (the
        // server ticks on the same skew the twin reports) and no swap
        // can race this reply: it positively pins pre-swap identity.
        if step % 4 == 3 {
            let v = Pcg64::with_stream(0x5e1f_aaaa, step as u64).normal_vec(twin.n_train());
            assert_bits_eq(
                &client.mvm(&v).unwrap(),
                &twin.operator().lattice.mvm(&v),
                "pre-swap mvm during skew drive",
            );
        }
    }
    panic!("80 skewed batches never crossed the threshold {threshold}");
}

/// Replay `batches` into a fresh fit of `(x, y)` — the deterministic
/// twin of the served model just before the rebalance.
fn replay(x: &[f64], y: &[f64], batches: &[(Vec<f64>, Vec<f64>)]) -> SimplexGp {
    let mut gp = fit(x, y);
    for (xb, yb) in batches {
        gp.ingest(xb, yb).unwrap();
    }
    gp
}

/// The headline pin: skewed streaming ingest triggers exactly one
/// background rebalance; every reply before the swap is byte-identical
/// to the never-rebalanced twin, every reply after it to the
/// `rebalance_pair` twin, and the transition is atomic (no reply
/// matches neither).
#[test]
fn rebalance_swaps_atomically_and_replies_stay_byte_identical() {
    let (x, y) = problem(240, 0x9b01);
    let mut twin = fit(&x, &y);
    let initial_skew = twin.skew_pair().unwrap().2;
    let threshold = (initial_skew * 1.1).max(1.3);

    let server = Server::start(
        fit(&x, &y),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            cluster: ClusterConfig {
                rebalance_skew: threshold,
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();

    let batches = drive_skew(&mut client, &mut twin, threshold);
    let (heavy, light, skew) = twin.skew_pair().unwrap();
    assert!(skew > threshold);

    // The post-swap twin: same history, then the same deterministic
    // pair rebuild the coordinator's background thread runs.
    let mut post = replay(&x, &y, &batches);
    assert_eq!(post.alpha(), twin.alpha(), "replay twin diverged");
    post.rebalance_pair(heavy, light).unwrap();
    assert!(post.last_solve_warm(), "rebalance re-solve must be warm");
    let post_skew = post.skew_pair().unwrap().2;
    assert!(
        post_skew <= threshold,
        "rebalance left skew {post_skew} above threshold {threshold} — \
         a second rebalance would fire and break the single-swap pin"
    );

    let n = twin.n_train();
    let mut rng = Pcg64::new(0x9b02);
    let v = rng.normal_vec(n);
    let pre_mvm = twin.operator().lattice.mvm(&v);
    let post_mvm = post.operator().lattice.mvm(&v);
    let xq: Vec<f64> = (0..3 * D).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let (pre_mean, pre_var) = twin.predict(&xq);
    let (post_mean, post_var) = post.predict(&xq);
    assert!(
        !bits_eq(&pre_mvm, &post_mvm),
        "pre/post lattices agree bitwise — the swap would be unobservable"
    );

    // Poll through the swap: every reply matches exactly one twin, and
    // once a reply matches the post twin, no later reply may match the
    // pre twin again.
    let t0 = Instant::now();
    let mut swapped = false;
    loop {
        let got = client.mvm(&v).unwrap();
        if bits_eq(&got, &pre_mvm) {
            assert!(
                !swapped,
                "reply reverted to the pre-rebalance model after the swap"
            );
        } else {
            assert_bits_eq(&got, &post_mvm, "post-swap mvm");
            swapped = true;
        }
        // The swap may land between the two requests, so this check is
        // two-sided as well: pre bits (only before the swap) or post
        // bits (which mark the swap) — never a third value.
        let (gm, gv) = client.predict_var(&xq, D).unwrap();
        if bits_eq(&gm, &pre_mean) && bits_eq(&gv, &pre_var) {
            assert!(!swapped, "predict reverted to the pre-rebalance model");
        } else {
            assert_bits_eq(&gm, &post_mean, "post-swap mean");
            assert_bits_eq(&gv, &post_var, "post-swap var");
            swapped = true;
        }
        if swapped && server.rebalances() >= 1 {
            break;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "background rebalance never committed (skew {skew} > {threshold})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // Exactly one swap, and the counters are coherent with it.
    assert_eq!(server.rebalances(), 1, "a second rebalance fired");
    assert_eq!(stat_f64(&mut client, "rebalances"), 1.0);
    assert_eq!(stat_f64(&mut client, "n"), twin.n_train() as f64);
    assert!(
        stat_f64(&mut client, "warm_iters") > 0.0,
        "warm ingest solves and the rebalance re-solve must count as warm"
    );
    assert_eq!(server.warm_iters(), stat_f64(&mut client, "warm_iters") as u64);

    // Steady state after the swap: still the post twin, bit for bit.
    for _ in 0..3 {
        assert_bits_eq(&client.mvm(&v).unwrap(), &post_mvm, "steady-state mvm");
    }
    let (gm, gv) = client.predict_var(&xq, D).unwrap();
    assert_bits_eq(&gm, &post_mean, "steady-state mean");
    assert_bits_eq(&gv, &post_var, "steady-state var");

    server.shutdown();
}

/// Fault leg: kill the heavy shard's worker link, then drive the same
/// skew. The rebalance must commit against the degraded pool with
/// every reply still byte-identical (the dead link's shard computes
/// in-thread), and afterwards the SURVIVING link re-syncs its swapped
/// replica — `remote_workers` comes back and post-rebalance jobs run
/// remotely again.
#[test]
fn killed_owning_worker_mid_rebalance_degrades_byte_identical_then_resyncs() {
    let (x, y) = problem(240, 0x9b11);
    let mut twin = fit(&x, &y);
    let initial_skew = twin.skew_pair().unwrap().2;
    let threshold = (initial_skew * 1.1).max(1.3);

    let workers: Vec<ShardWorker> = (0..2)
        .map(|_| {
            ShardWorker::start(WorkerConfig {
                listen: "127.0.0.1:0".to_string(),
                ..WorkerConfig::default()
            })
            .unwrap()
        })
        .collect();
    let server = Server::start(
        fit(&x, &y),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            debug_ops: true,
            cluster: ClusterConfig {
                workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
                rebalance_skew: threshold,
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let t0 = Instant::now();
    while stat_f64(&mut client, "remote_workers") < 2.0 {
        assert!(t0.elapsed().as_secs() < 30, "workers never synced");
        std::thread::sleep(Duration::from_millis(25));
    }

    // Kill the link serving shard 0 — the shard the spread batches
    // will fatten into the heavy half of the rebalanced pair. Its jobs
    // degrade to in-thread compute from here on.
    let reply = debug_op(
        &server.local_addr,
        "{\"id\":70,\"op\":\"debug_kill_worker\",\"shard\":0}",
    );
    assert!(reply.contains("\"killed\":1"), "got: {reply}");

    let batches = drive_skew(&mut client, &mut twin, threshold);
    let (heavy, light, _) = twin.skew_pair().unwrap();
    assert_eq!(heavy, 0, "spread batches were meant to fatten shard 0");
    let mut post = replay(&x, &y, &batches);
    post.rebalance_pair(heavy, light).unwrap();

    let n = twin.n_train();
    let mut rng = Pcg64::new(0x9b12);
    let v = rng.normal_vec(n);
    let pre_mvm = twin.operator().lattice.mvm(&v);
    let post_mvm = post.operator().lattice.mvm(&v);

    // Degraded but byte-identical through the swap.
    let t1 = Instant::now();
    let mut swapped = false;
    loop {
        let got = client.mvm(&v).unwrap();
        if bits_eq(&got, &pre_mvm) {
            assert!(!swapped, "reply reverted after the swap");
        } else {
            assert_bits_eq(&got, &post_mvm, "post-swap degraded mvm");
            swapped = true;
        }
        if swapped && server.rebalances() >= 1 {
            break;
        }
        assert!(
            t1.elapsed().as_secs() < 30,
            "rebalance never committed on the degraded pool"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(server.rebalances(), 1);
    assert!(server.warm_iters() > 0);

    // Eventual resync: the commit desynced both pair replicas; the
    // dead link stays dead (shard 0 keeps computing in-thread), but
    // the surviving link must reconnect, refresh its replica from the
    // swapped model, and serve shard 1 remotely again — all while the
    // replies stay byte-identical to the post twin.
    let t2 = Instant::now();
    loop {
        let before: u64 = workers.iter().map(|w| w.served()).sum();
        assert_bits_eq(&client.mvm(&v).unwrap(), &post_mvm, "post-recovery mvm");
        let after: u64 = workers.iter().map(|w| w.served()).sum();
        if stat_f64(&mut client, "remote_workers") >= 1.0 && after > before {
            break;
        }
        assert!(
            t2.elapsed().as_secs() < 30,
            "surviving worker never re-synced its swapped replica"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// PR 10's named follow-on leg: tail latency *during* the swap under a
/// sustained (paced, open-loop-style) ingest stream that keeps running
/// while the background build is in flight. Ingests that land mid-build
/// invalidate the plan (fingerprint check) and force a replan, so the
/// commit can land after ANY prefix of the extra batches — the test
/// therefore checks every reply against the full family of legal
/// states: the streaming twin (pre-swap), or "rebalance committed
/// after extra batch j, remaining batches ingested into the swapped
/// model" for some j. A reply matching none of those is a torn swap.
/// Every request is counted against its reply (none lost), every
/// latency is recorded, and the p99 across the swap window is printed
/// (the `serving_load` bench's `tcp_rebalance` mode measures the same
/// window under a true open-loop arrival process).
#[test]
fn tail_latency_and_byte_identity_under_sustained_ingest_through_swap() {
    let (x, y) = problem(240, 0x9b31);
    let mut twin = fit(&x, &y);
    let initial_skew = twin.skew_pair().unwrap().2;
    let threshold = (initial_skew * 1.1).max(1.3);

    let server = Server::start(
        fit(&x, &y),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            cluster: ClusterConfig {
                rebalance_skew: threshold,
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();

    // Phase 1: the skew-driving ingest stream (lockstep twin). The
    // final batch crosses the threshold, so the background build
    // launches while the stream is still running.
    let batches = drive_skew(&mut client, &mut twin, threshold);

    let xq: Vec<f64> = {
        let mut rng = Pcg64::new(0x9b32);
        (0..3 * D).map(|_| rng.uniform_in(-2.0, 2.0)).collect()
    };
    let mut latencies_us: Vec<f64> = Vec::new();
    let (mut sent, mut answered) = (0usize, 0usize);
    let mut extras: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
    // Some(post-twin) once the swap has been observed and its commit
    // point identified; the twin then ingests the remaining extras in
    // lockstep like the server does.
    let mut post: Option<SimplexGp> = None;
    let mut swap_probe: Option<usize> = None;

    // One predict probe: latency-timed, byte-checked against the legal
    // state family. Returns whether the reply came from the swapped
    // model.
    let mut probe = |client: &mut Client,
                     twin: &SimplexGp,
                     extras: &[(Vec<f64>, Vec<f64>)],
                     post: &mut Option<SimplexGp>,
                     latencies_us: &mut Vec<f64>,
                     sent: &mut usize,
                     answered: &mut usize|
     -> bool {
        *sent += 1;
        let t = Instant::now();
        let (gm, gv) = client.predict_var(&xq, D).unwrap();
        latencies_us.push(t.elapsed().as_secs_f64() * 1e6);
        *answered += 1;
        if let Some(p) = post {
            let (pm, pv) = p.predict(&xq);
            if bits_eq(&gm, &pm) && bits_eq(&gv, &pv) {
                return true;
            }
            panic!("reply matches neither the pre- nor the committed post-swap state");
        }
        let (tm, tv) = twin.predict(&xq);
        if bits_eq(&gm, &tm) && bits_eq(&gv, &tv) {
            return false;
        }
        // First reply off the streaming twin: the swap committed after
        // some prefix of the extra batches. Identify it — rebuild each
        // candidate "commit after extra j" state and match bitwise.
        for j in 0..=extras.len() {
            let mut cand = replay(&x, &y, &batches);
            for (xb, yb) in &extras[..j] {
                cand.ingest(xb, yb).unwrap();
            }
            let (h, l, _) = cand.skew_pair().unwrap();
            cand.rebalance_pair(h, l).unwrap();
            for (xb, yb) in &extras[j..] {
                cand.ingest(xb, yb).unwrap();
            }
            let (cm, cv) = cand.predict(&xq);
            if bits_eq(&gm, &cm) && bits_eq(&gv, &cv) {
                *post = Some(cand);
                return true;
            }
        }
        panic!("swapped reply matches no legal commit point (torn swap)");
    };

    // Phase 2: keep the ingest stream going at a fixed pace while the
    // build runs, probing between sends. Tight clusters (odd skew_batch
    // steps) barely move the skew — they invalidate in-flight plans
    // without re-arming a second rebalance.
    for step in 0..6 {
        let (xb, yb) = skew_batch(1001 + 2 * step, 4);
        sent += 1;
        let t = Instant::now();
        let n_live = client.ingest(&xb, &yb, D).unwrap();
        latencies_us.push(t.elapsed().as_secs_f64() * 1e6);
        answered += 1;
        twin.ingest(&xb, &yb).unwrap();
        if let Some(p) = post.as_mut() {
            p.ingest(&xb, &yb).unwrap();
        }
        assert_eq!(n_live, twin.n_train(), "extra batch {step}: ingest diverged");
        extras.push((xb, yb));
        if probe(
            &mut client,
            &twin,
            &extras,
            &mut post,
            &mut latencies_us,
            &mut sent,
            &mut answered,
        ) && swap_probe.is_none()
        {
            swap_probe = Some(sent);
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // Phase 3: the stream has drained; probe until the swap commits
    // (the final plan can no longer be invalidated).
    let t0 = Instant::now();
    loop {
        if probe(
            &mut client,
            &twin,
            &extras,
            &mut post,
            &mut latencies_us,
            &mut sent,
            &mut answered,
        ) && swap_probe.is_none()
        {
            swap_probe = Some(sent);
        }
        if post.is_some() && server.rebalances() >= 1 {
            break;
        }
        assert!(
            t0.elapsed().as_secs() < 60,
            "rebalance never committed under the sustained stream"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // Steady state: still the committed post twin, and exactly one swap.
    for _ in 0..3 {
        assert!(probe(
            &mut client,
            &twin,
            &extras,
            &mut post,
            &mut latencies_us,
            &mut sent,
            &mut answered,
        ));
    }
    assert_eq!(server.rebalances(), 1, "a second rebalance fired");
    assert_eq!(sent, answered, "a request went unanswered across the swap");

    let mut sorted = latencies_us.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = sorted[((sorted.len() as f64 * 0.99) as usize).min(sorted.len() - 1)];
    println!(
        "tcp_rebalance leg: {} requests through the swap window, p99 {:.1} µs, \
         swap first observed at request {}",
        sent,
        p99,
        swap_probe.unwrap()
    );

    server.shutdown();
}

/// The rebalance-off default: `rebalance_skew = 0` must never count a
/// rebalance no matter the skew, while the warm/cold iteration split
/// still tracks the streaming solves.
#[test]
fn rebalance_off_counts_nothing_and_warm_iters_track_ingest() {
    let (x, y) = problem(200, 0x9b21);
    let mut twin = fit(&x, &y);
    let server = Server::start(
        fit(&x, &y),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    assert_eq!(stat_f64(&mut client, "rebalances"), 0.0);
    assert_eq!(stat_f64(&mut client, "warm_iters"), 0.0);
    assert_eq!(stat_f64(&mut client, "cold_iters"), 0.0);

    // Drive well past any reasonable threshold: with rebalancing off
    // the skew is free to grow and the model must never swap.
    for step in 0..12 {
        let (xb, yb) = skew_batch(step, 5);
        client.ingest(&xb, &yb, D).unwrap();
        twin.ingest(&xb, &yb).unwrap();
    }
    // Give any (buggy) background machinery time to fire, then pin.
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(server.rebalances(), 0, "rebalance fired with skew = 0");
    assert_eq!(stat_f64(&mut client, "rebalances"), 0.0);
    assert!(
        stat_f64(&mut client, "warm_iters") > 0.0,
        "incremental ingest solves must count as warm"
    );
    assert_eq!(stat_f64(&mut client, "cold_iters"), 0.0);

    // And the served model is still the plain streaming twin.
    let v = Pcg64::new(0x9b22).normal_vec(twin.n_train());
    assert_bits_eq(
        &client.mvm(&v).unwrap(),
        &twin.operator().lattice.mvm(&v),
        "rebalance-off mvm",
    );

    server.shutdown();
}

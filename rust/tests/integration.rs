//! Cross-module integration tests: dataset → lattice → solver → model →
//! coordinator, plus native-vs-PJRT parity on a *real* built lattice
//! (the unit tests cover each layer; these cover the seams).

use simplex_gp::baselines::ExactGp;
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::datasets::{generate, split_standardize};
use simplex_gp::gp::{train, GpConfig, SimplexGp, TrainConfig};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
#[cfg(feature = "pjrt")]
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::mvm::{MvmOperator, SimplexMvm};
use simplex_gp::util::stats::{cosine_error, rmse};
use simplex_gp::util::Pcg64;

#[test]
fn dataset_to_model_pipeline() {
    // Full path: generator → split/standardize → fit → predict.
    let ds = generate("protein", 1800, 3);
    let sp = split_standardize(&ds, 4);
    let d = 9;
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 1.0);
    let gp = SimplexGp::fit(
        &sp.train.x,
        &sp.train.y,
        d,
        kernel,
        0.1,
        GpConfig::default(),
    )
    .unwrap();
    let pred = gp.predict_mean(&sp.test.x);
    let err = rmse(&pred, &sp.test.y);
    let base = rmse(&vec![0.0; sp.test.n()], &sp.test.y);
    assert!(err < base, "model no better than mean: {err} vs {base}");
}

#[test]
fn trained_model_beats_untrained() {
    let ds = generate("precipitation", 1500, 5);
    let sp = split_standardize(&ds, 6);
    let d = 3;
    let cfg = TrainConfig {
        epochs: 10,
        probes: 4,
        ..TrainConfig::default()
    };
    let out = train(
        &sp.train.x,
        &sp.train.y,
        &sp.val.x,
        &sp.val.y,
        d,
        KernelFamily::Rbf,
        cfg,
    )
    .unwrap();
    let trained = rmse(&out.model.predict_mean(&sp.test.x), &sp.test.y);
    // Untrained reference: unit hyperparameters.
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let gp0 = SimplexGp::fit(
        &sp.train.x,
        &sp.train.y,
        d,
        kernel,
        0.1,
        GpConfig::default(),
    )
    .unwrap();
    let untrained = rmse(&gp0.predict_mean(&sp.test.x), &sp.test.y);
    assert!(
        trained <= untrained * 1.05,
        "training hurt: {trained} vs {untrained}"
    );
}

#[test]
fn simplex_and_exact_gp_agree_on_easy_problem() {
    let ds = generate("protein", 900, 7);
    let sp = split_standardize(&ds, 8);
    let d = 9;
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.5);
    let noise = 0.1;
    let sgp = SimplexGp::fit(
        &sp.train.x,
        &sp.train.y,
        d,
        kernel.clone(),
        noise,
        GpConfig::default(),
    )
    .unwrap();
    let egp = ExactGp::fit(&sp.train.x, &sp.train.y, d, kernel, noise, 1e-4).unwrap();
    let ps = sgp.predict_mean(&sp.test.x);
    let pe = egp.predict_mean(&sp.test.x);
    let cos = cosine_error(&ps, &pe);
    assert!(cos < 0.15, "simplex vs exact prediction cosine error {cos}");
    // And both beat the trivial predictor.
    let base = rmse(&vec![0.0; sp.test.n()], &sp.test.y);
    assert!(rmse(&ps, &sp.test.y) < base);
    assert!(rmse(&pe, &sp.test.y) < base);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_matches_native_on_real_lattice() {
    // Requires `make artifacts`. Skips (with a note) if absent.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = simplex_gp::runtime::PjrtRuntime::new(&dir).unwrap();
    // d=3 bucket: n ≤ 2048, m+1 ≤ 4096, r=1.
    let ds = generate("precipitation", 1600, 9);
    let sp = split_standardize(&ds, 10);
    let d = 3;
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let lat = PermutohedralLattice::build(&sp.train.x, d, &kernel, 1);
    assert!(lat.m + 1 <= 4096, "lattice too large for the bucket: {}", lat.m);
    let px = simplex_gp::runtime::SimplexPjrtMvm::new(&rt, &lat, 1.0).unwrap();
    let mut rng = Pcg64::new(11);
    let v = rng.normal_vec(lat.n);
    let native = lat.mvm(&v);
    let pjrt = px.mvm(&v).unwrap();
    // f32 artifact vs f64 native: agree to single precision.
    let err = simplex_gp::util::stats::rel_l2(&pjrt, &native);
    assert!(err < 1e-4, "pjrt vs native rel err {err}");
}

#[test]
fn serve_predictions_match_direct_calls() {
    let ds = generate("elevators", 900, 12);
    let sp = split_standardize(&ds, 13);
    let d = 17;
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 1.0);
    let gp = SimplexGp::fit(
        &sp.train.x,
        &sp.train.y,
        d,
        kernel,
        0.1,
        GpConfig::default(),
    )
    .unwrap();
    let probe = sp.test.x[..4 * d].to_vec();
    let direct = gp.predict_mean(&probe);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        ..ServeConfig::default()
    };
    let server = Server::start(gp, cfg).unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let served = client.predict(&probe, d).unwrap();
    for i in 0..4 {
        assert!((served[i] - direct[i]).abs() < 1e-9);
    }
    server.shutdown();
}

#[test]
fn mvm_operator_consistency_across_backends() {
    // SimplexMvm (operator) == lattice.mvm (direct) == symmetrized
    // within tolerance.
    let ds = generate("keggdirected", 1200, 14);
    let sp = split_standardize(&ds, 15);
    let d = 20;
    let mut kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.2);
    kernel.outputscale = 1.7;
    let op = SimplexMvm::build(&sp.train.x, d, &kernel, 1);
    let mut rng = Pcg64::new(16);
    let v = rng.normal_vec(op.len());
    let a = op.mvm(&v);
    let direct: Vec<f64> = op.lattice.mvm(&v).iter().map(|x| x * 1.7).collect();
    for i in 0..a.len() {
        assert!((a[i] - direct[i]).abs() < 1e-12);
    }
    let sym = SimplexMvm::build(&sp.train.x, d, &kernel, 1).with_symmetrize(true);
    let b = sym.mvm(&v);
    let cos = cosine_error(&a, &b);
    assert!(cos < 0.02, "symmetrization changed the operator too much: {cos}");
}

//! Multi-node shard transport equivalence suite (loopback).
//!
//! Pins the PR 5 contract from docs/PROTOCOL.md: a coordinator whose
//! shard pool runs over TCP to remote `shard-worker` endpoints replies
//! **byte-identically** (float bits through the JSON wire) to one
//! running the in-process pool — for `mvm` and for `ingest`-then-`mvm`
//! — at P ∈ {2, 3}; and killing a remote worker mid-stream degrades to
//! correct (still byte-identical) replies without wedging the batcher,
//! extending PR 4's deterministic `debug_kill_worker` failure tests to
//! the remote pool.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use simplex_gp::coordinator::frame::WireEncoding;
use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::util::Pcg64;

/// Deterministic training problem: `SimplexGp::fit` has no hidden
/// randomness, so two fits of the same data are the same model bit for
/// bit — the basis for comparing a local-pool server against a
/// remote-pool server.
fn problem(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

fn fit(x: &[f64], y: &[f64], d: usize, shards: usize) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
}

fn start_workers(count: usize) -> Vec<ShardWorker> {
    (0..count)
        .map(|_| {
            ShardWorker::start(WorkerConfig {
                listen: "127.0.0.1:0".to_string(),
                ..WorkerConfig::default()
            })
            .unwrap()
        })
        .collect()
}

fn remote_cfg(workers: &[ShardWorker]) -> ClusterConfig {
    ClusterConfig {
        workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
        ..ClusterConfig::default()
    }
}

/// Block until the server reports `want` connected-and-synced remote
/// workers (replicas sync in the background after `Server::start`).
fn wait_remote_synced(client: &mut Client, want: usize) {
    let t0 = std::time::Instant::now();
    loop {
        let got = client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0) as i64;
        if got == want as i64 {
            return;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "remote workers never synced: {got}/{want}"
        );
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: row {i} ({} vs {})",
            a[i],
            b[i]
        );
    }
}

#[test]
fn remote_mvm_byte_identical_to_local_pool() {
    let d = 2;
    let (x, y) = problem(260, d, 11);
    for shards in [2usize, 3] {
        // Reference: the direct in-process sharded MVM.
        let reference = fit(&x, &y, d, shards);
        let n = reference.n_train();
        let mut rng = Pcg64::new(100 + shards as u64);
        let v = rng.normal_vec(n);
        let direct = reference.operator().lattice.mvm(&v);

        // Local-pool server.
        let local_server = Server::start(
            fit(&x, &y, d, shards),
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut local_client = Client::connect(&local_server.local_addr).unwrap();
        let local_u = local_client.mvm(&v).unwrap();

        // Remote-pool server: 2 workers; at P = 3 worker 0 holds shards
        // {0, 2} (round-robin assignment).
        let workers = start_workers(2);
        let remote_server = Server::start(
            fit(&x, &y, d, shards),
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                cluster: remote_cfg(&workers),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut remote_client = Client::connect(&remote_server.local_addr).unwrap();
        wait_remote_synced(&mut remote_client, 2);
        let remote_u = remote_client.mvm(&v).unwrap();

        assert_bits_eq(&local_u, &direct, &format!("P={shards} local vs direct"));
        assert_bits_eq(&remote_u, &direct, &format!("P={shards} remote vs direct"));
        // The remote path must actually have served the jobs (not the
        // fallback): every shard's job lands on some worker.
        let served: u64 = workers.iter().map(|w| w.served()).sum();
        assert!(
            served as usize >= shards,
            "P={shards}: only {served} remote jobs served"
        );
        // Both workers hold their round-robin assignment.
        let held: Vec<Vec<usize>> =
            workers.iter().map(|w| w.held_shards()).collect();
        for p in 0..shards {
            assert!(
                held[p % 2].contains(&p),
                "shard {p} not held by worker {} (held: {held:?})",
                p % 2
            );
        }

        remote_server.shutdown();
        local_server.shutdown();
        for w in workers {
            w.shutdown();
        }
    }
}

#[test]
fn remote_ingest_byte_identical_to_local_pool() {
    let d = 2;
    let (x, y) = problem(240, d, 21);
    let (xi, yi) = problem(12, d, 22);
    for shards in [2usize, 3] {
        let workers = start_workers(2);
        let mk_cfg = |cluster: Option<ClusterConfig>| ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            cluster: cluster.unwrap_or_default(),
            ..ServeConfig::default()
        };
        let local_server = Server::start(fit(&x, &y, d, shards), mk_cfg(None)).unwrap();
        let remote_server = Server::start(
            fit(&x, &y, d, shards),
            mk_cfg(Some(remote_cfg(&workers))),
        )
        .unwrap();
        let mut local_client = Client::connect(&local_server.local_addr).unwrap();
        let mut remote_client = Client::connect(&remote_server.local_addr).unwrap();
        wait_remote_synced(&mut remote_client, 2);

        // Identical ingests land on the identical (lightest) shard and
        // grow both models to the same n.
        let n_local = local_client.ingest(&xi, &yi, d).unwrap();
        let n_remote = remote_client.ingest(&xi, &yi, d).unwrap();
        assert_eq!(n_local, 252);
        assert_eq!(n_remote, 252);

        // Post-ingest MVMs ride the *patched remote replica* (per-link
        // FIFO: the ingest propagation precedes this job) and must match
        // the local pool bit for bit.
        let mut rng = Pcg64::new(200 + shards as u64);
        let v = rng.normal_vec(n_local);
        let served_before: u64 = workers.iter().map(|w| w.served()).sum();
        let local_u = local_client.mvm(&v).unwrap();
        let remote_u = remote_client.mvm(&v).unwrap();
        assert_bits_eq(
            &remote_u,
            &local_u,
            &format!("P={shards} post-ingest remote vs local"),
        );
        // Replicas stayed synced (no fallback, no resync churn): the
        // remote jobs really were served against the patched lattices.
        let served_after: u64 = workers.iter().map(|w| w.served()).sum();
        assert!(
            served_after >= served_before + shards as u64,
            "P={shards}: post-ingest mvm did not run remotely \
             ({served_before} -> {served_after})"
        );
        let still = remote_client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|s| s.as_f64());
        assert_eq!(still, Some(2.0), "P={shards}: replicas lost sync");

        remote_server.shutdown();
        local_server.shutdown();
        for w in workers {
            w.shutdown();
        }
    }
}

#[test]
fn killed_remote_worker_degrades_to_byte_identical_replies() {
    // PR 4's deterministic kill, extended to the remote pool: the
    // debug op disables the worker link serving shard 0; its shards
    // fall back to in-thread compute and replies stay byte-identical,
    // mid-stream, without wedging the batcher.
    let d = 2;
    let (x, y) = problem(250, d, 31);
    let reference = fit(&x, &y, d, 2);
    let n = reference.n_train();
    let mut rng = Pcg64::new(300);
    let v = rng.normal_vec(n);
    let direct = reference.operator().lattice.mvm(&v);

    let workers = start_workers(2);
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: remote_cfg(&workers),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);

    let before = client.mvm(&v).unwrap();
    assert_bits_eq(&before, &direct, "pre-kill");

    // Kill the link serving shard 0 (raw request — the op is
    // debug-only).
    let stream = TcpStream::connect(server.local_addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"id\":99,\"op\":\"debug_kill_worker\",\"shard\":0}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"killed\":1"), "got: {line}");

    let after = client.mvm(&v).unwrap();
    assert_bits_eq(&after, &direct, "post-kill");

    // Harder failure: stop the OTHER worker's process entirely (socket
    // gone, not just the link). The first job after the shutdown may
    // fail mid-roundtrip; the batcher must still answer byte-
    // identically via the in-thread fallback.
    let mut workers = workers;
    let w1 = workers.remove(1);
    w1.shutdown();
    let aftermost = client.mvm(&v).unwrap();
    assert_bits_eq(&aftermost, &direct, "post-shutdown");

    // Batcher alive and stats coherent.
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("shards").and_then(|s| s.as_f64()), Some(2.0));
    assert_eq!(
        stats.get("cluster_workers").and_then(|s| s.as_f64()),
        Some(2.0)
    );
    let served = stats.get("served").and_then(|s| s.as_f64()).unwrap();
    assert!(served >= 3.0, "served={served}");

    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn json_encoding_and_v1_workers_stay_byte_identical() {
    // PR 7: the byte-identity contract is encoding-independent. Two
    // downgrade paths to pure-JSON frames, same pinned replies:
    //  (a) v2 workers with the coordinator forced onto `json`;
    //  (b) workers pinned to protocol v1, so the coordinator's v2+bin1
    //      hello is rejected and it retries at v1 on the same
    //      connection (PROTOCOL.md §Versioning).
    let d = 2;
    let shards = 2;
    let (x, y) = problem(230, d, 41);
    let reference = fit(&x, &y, d, shards);
    let n = reference.n_train();
    let mut rng = Pcg64::new(400);
    let v = rng.normal_vec(n);
    let direct = reference.operator().lattice.mvm(&v);

    let forced_json = |w: &[ShardWorker]| {
        let mut c = remote_cfg(w);
        c.encoding = WireEncoding::Json;
        c
    };
    let v1_workers = || -> Vec<ShardWorker> {
        (0..2)
            .map(|_| {
                ShardWorker::start(WorkerConfig {
                    listen: "127.0.0.1:0".to_string(),
                    max_protocol_version: 1,
                    ..WorkerConfig::default()
                })
                .unwrap()
            })
            .collect()
    };

    for case in ["forced_json", "v1_workers"] {
        let workers = if case == "v1_workers" {
            v1_workers()
        } else {
            start_workers(2)
        };
        let cluster = if case == "v1_workers" {
            remote_cfg(&workers) // requests bin1; must negotiate down
        } else {
            forced_json(&workers)
        };
        let server = Server::start(
            fit(&x, &y, d, shards),
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                cluster,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();
        wait_remote_synced(&mut client, 2);

        let u = client.mvm(&v).unwrap();
        assert_bits_eq(&u, &direct, &format!("{case} vs direct"));
        let served: u64 = workers.iter().map(|w| w.served()).sum();
        assert!(
            served as usize >= shards,
            "{case}: only {served} remote jobs served"
        );

        server.shutdown();
        for w in workers {
            w.shutdown();
        }
    }
}

#[test]
fn shed_shards_serve_remotely_and_stay_byte_identical() {
    // PR 7 shed mode with healthy workers: the coordinator drops its
    // local shard lattices at pool start, serves MVMs entirely off the
    // worker replicas (zero on-demand rebuilds), and the replies stay
    // byte-identical to the resident-lattice reference.
    let d = 2;
    let shards = 2;
    let (x, y) = problem(240, d, 51);
    let reference = fit(&x, &y, d, shards);
    let n = reference.n_train();
    let mut rng = Pcg64::new(500);
    let v = rng.normal_vec(n);
    let direct = reference.operator().lattice.mvm(&v);

    let workers = start_workers(2);
    let mut cluster = remote_cfg(&workers);
    cluster.shed_shards = true;
    let server = Server::start(
        fit(&x, &y, d, shards),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            cluster,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);

    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("shed_shards").and_then(|s| s.as_f64()),
        Some(shards as f64),
        "all worker-served shards shed at pool start"
    );

    let u = client.mvm(&v).unwrap();
    assert_bits_eq(&u, &direct, "shed remote vs direct");

    // The jobs really ran on the workers — no rebuild was needed and
    // the shards are still shed afterwards.
    assert_eq!(server.shed_rebuilds(), 0, "healthy workers forced a rebuild");
    let served: u64 = workers.iter().map(|w| w.served()).sum();
    assert!(served as usize >= shards, "only {served} remote jobs served");
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("shed_shards").and_then(|s| s.as_f64()),
        Some(shards as f64)
    );

    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

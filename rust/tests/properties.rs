//! Property-based sweeps (hand-rolled: proptest isn't in the vendored
//! registry). Each property is exercised across a seeded family of
//! random shapes/dimensions/lengthscales — failures print the exact
//! (seed, d, n, ℓ) tuple for replay.

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::filter::exact_mvm;
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::linalg::Mat;
use simplex_gp::mvm::{DenseMvm, MvmOperator, Shifted, SimplexMvm};
use simplex_gp::solvers::{cg, CgOptions};
use simplex_gp::stencil::{fourier_coverage, optimal_spacing, spatial_coverage, Stencil};
use simplex_gp::util::json::Json;
use simplex_gp::util::stats::{cosine_error, dot};
use simplex_gp::util::Pcg64;

const FAMILIES: [KernelFamily; 4] = [
    KernelFamily::Rbf,
    KernelFamily::Matern12,
    KernelFamily::Matern32,
    KernelFamily::Matern52,
];

fn case_rng(seed: u64) -> Pcg64 {
    Pcg64::with_stream(0x9e37_79b9, seed)
}

#[test]
fn barycentric_weights_valid_across_shapes() {
    for case in 0..40u64 {
        let mut rng = case_rng(case);
        let d = 1 + rng.below(20);
        let n = 20 + rng.below(200);
        let ell = rng.uniform_in(0.1, 3.0);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, ell);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        for i in 0..n {
            let row = &lat.weights[i * (d + 1)..(i + 1) * (d + 1)];
            let sum: f64 = row.iter().sum();
            assert!(
                (sum - 1.0).abs() < 1e-9,
                "case {case} (d={d} n={n} ell={ell}): weight sum {sum}"
            );
            for &w in row {
                assert!(w >= -1e-12, "case {case}: negative weight {w}");
            }
        }
    }
}

#[test]
fn splat_slice_adjoint_across_shapes() {
    for case in 0..30u64 {
        let mut rng = case_rng(1000 + case);
        let d = 1 + rng.below(12);
        let n = 30 + rng.below(150);
        let ell = rng.uniform_in(0.2, 2.0);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, ell);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        let v = rng.normal_vec(n);
        let z = rng.normal_vec(lat.m + 1);
        let lhs = dot(&lat.splat(&v, 1), &z);
        let rhs = dot(&v, &lat.slice(&z, 1));
        assert!(
            (lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()),
            "case {case} (d={d} n={n}): {lhs} vs {rhs}"
        );
    }
}

#[test]
fn symmetrized_mvm_is_symmetric_across_shapes() {
    for case in 0..15u64 {
        let mut rng = case_rng(2000 + case);
        let d = 2 + rng.below(10);
        let n = 50 + rng.below(150);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
        let op = SimplexMvm::build(&x, d, &k, 1).with_symmetrize(true);
        let u = rng.normal_vec(n);
        let v = rng.normal_vec(n);
        let a = dot(&u, &op.mvm(&v));
        let b = dot(&v, &op.mvm(&u));
        assert!(
            (a - b).abs() < 1e-9 * (1.0 + a.abs()),
            "case {case} (d={d} n={n}): asym {a} vs {b}"
        );
    }
}

#[test]
fn mvm_tracks_exact_across_families_and_lengthscales() {
    for case in 0..12u64 {
        let mut rng = case_rng(3000 + case);
        let d = 2 + rng.below(4);
        let n = 120;
        let fam = FAMILIES[rng.below(FAMILIES.len())];
        let ell = rng.uniform_in(0.5, 2.0);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(fam, d, ell);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        let v = rng.normal_vec(n);
        let err = cosine_error(&lat.mvm(&v), &exact_mvm(&k, &x, d, &v));
        assert!(
            err < 0.12,
            "case {case} ({fam:?} d={d} ell={ell:.2}): cosine err {err}"
        );
    }
}

#[test]
fn cg_solves_shifted_simplex_systems() {
    // The production solve: (symmetrized lattice MVM + σ²I) is solvable
    // to tight tolerance across shapes, and the solution satisfies the
    // residual bound.
    for case in 0..8u64 {
        let mut rng = case_rng(4000 + case);
        let d = 2 + rng.below(6);
        let n = 100 + rng.below(200);
        let noise = rng.uniform_in(0.05, 0.5);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
        let op = SimplexMvm::build(&x, d, &k, 1).with_symmetrize(true);
        let shifted = Shifted::new(&op, noise);
        let b = rng.normal_vec(n);
        let res = cg(
            &shifted,
            &b,
            CgOptions {
                tol: 1e-6,
                max_iters: 500,
                min_iters: 1,
            },
        );
        let ax = shifted.mvm(&res.x);
        let rnorm: f64 = ax
            .iter()
            .zip(&b)
            .map(|(a, bb)| (a - bb) * (a - bb))
            .sum::<f64>()
            .sqrt();
        assert!(
            rnorm / (n as f64).sqrt() < 1e-5,
            "case {case} (d={d} n={n} noise={noise:.2}): residual {rnorm}"
        );
    }
}

#[test]
fn stencil_balance_across_families_orders() {
    for fam in FAMILIES {
        for r in 1..=4usize {
            let s = optimal_spacing(fam, r);
            let gap = spatial_coverage(fam, r, s) - fourier_coverage(fam, s);
            assert!(gap.abs() < 2e-3, "{fam:?} r={r}: gap {gap}");
            let st = Stencil::build(fam, r);
            assert_eq!(st.taps.len(), 2 * r + 1);
            assert!((st.taps[r] - 1.0).abs() < 1e-12);
        }
    }
}

#[test]
fn cg_matches_dense_solve_on_random_spd() {
    for case in 0..10u64 {
        let mut rng = case_rng(5000 + case);
        let n = 20 + rng.below(60);
        let mut b = Mat::zeros(n, n);
        for i in 0..n * n {
            b.data[i] = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * rng.uniform_in(0.1, 2.0));
        let rhs = rng.normal_vec(n);
        let dense_x = simplex_gp::linalg::solve_spd(&a, &rhs).unwrap();
        let op = DenseMvm { mat: a };
        let res = cg(
            &op,
            &rhs,
            CgOptions {
                tol: 1e-12,
                max_iters: 1000,
                min_iters: 1,
            },
        );
        for i in 0..n {
            assert!(
                (res.x[i] - dense_x[i]).abs() < 1e-6,
                "case {case}: x[{i}] {} vs {}",
                res.x[i],
                dense_x[i]
            );
        }
    }
}

#[test]
fn json_roundtrip_random_values() {
    for case in 0..30u64 {
        let mut rng = case_rng(6000 + case);
        // Random nested structure.
        fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.next_u64() & 1 == 0),
                2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
                3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for k in 0..rng.below(4) {
                        m.insert(format!("k{k}"), random_json(rng, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: reparse failed: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
    }
}

#[test]
fn embed_only_is_partition_of_unity_inside_hull() {
    // Points interpolated near training data keep weight mass ≈ 1.
    for case in 0..10u64 {
        let mut rng = case_rng(7000 + case);
        let d = 2 + rng.below(6);
        let n = 300;
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        // Probe at training points themselves.
        let (off, w) = lat.embed_only(&x[..20 * d], &k);
        for i in 0..20 {
            let mass: f64 = w[i * (d + 1)..(i + 1) * (d + 1)].iter().sum();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "case {case} point {i}: mass {mass}"
            );
            assert!(off[i * (d + 1)..(i + 1) * (d + 1)].iter().all(|&o| o != 0));
        }
    }
}

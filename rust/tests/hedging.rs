//! Hedged shard redundancy: deterministic fault-injection tests for
//! every degradation path (PR 6).
//!
//! The straggler is injected with the debug-gated `debug_delay_worker`
//! op (the coordinator-side twin of PR 4's `debug_kill_worker`): the
//! link/worker serving a chosen shard sleeps a fixed delay before every
//! job. Against that deterministic slow worker this suite pins the
//! hedging contract from docs/DEPLOYMENT.md §Hedged redundancy:
//!
//! - hedged replies are **byte-identical** to local compute (the backup
//!   holds a fingerprint-verified replica; the race loser is discarded
//!   by job id, so which copy wins never shows in the bytes);
//! - the hedge fires at `hedge_ms`, not at `result_timeout` — with one
//!   slow worker, enabling hedging cuts p99 by ≥ 3× (the ISSUE 6
//!   acceptance gate, enforced here rather than in the bench);
//! - a hedge-winning backup leaves stats and job bookkeeping coherent;
//! - with hedging off the behavior is PR 5's, bit for bit: slow worker
//!   waited out, `hedged == hedge_wins == 0`;
//! - the local (in-process) pool hedges too: no backup workers exist,
//!   so the hedge IS the in-thread fallback, fired early.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::loadgen::LatencyHistogram;
use simplex_gp::util::Pcg64;

fn problem(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y)
}

/// `SimplexGp::fit` is deterministic: refitting the same data yields
/// the same model bit for bit, so a separately fit reference model
/// predicts the served replies exactly.
fn fit(x: &[f64], y: &[f64], d: usize, shards: usize) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
}

fn start_workers(count: usize) -> Vec<ShardWorker> {
    (0..count)
        .map(|_| {
            ShardWorker::start(WorkerConfig {
                listen: "127.0.0.1:0".to_string(),
                ..WorkerConfig::default()
            })
            .unwrap()
        })
        .collect()
}

fn cluster_cfg(workers: &[ShardWorker], hedge_ms: u64) -> ClusterConfig {
    ClusterConfig {
        workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
        hedge: match hedge_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        ..ClusterConfig::default()
    }
}

fn wait_remote_synced(client: &mut Client, want: usize) {
    let t0 = Instant::now();
    loop {
        let got = client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0) as i64;
        if got == want as i64 {
            return;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "remote workers never synced: {got}/{want}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: row {i} ({} vs {})",
            a[i],
            b[i]
        );
    }
}

/// Inject the deterministic straggler: the worker/link serving `shard`
/// sleeps `delay_ms` before every subsequent job.
fn delay_worker(addr: &std::net::SocketAddr, shard: usize, delay_ms: u64) {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(
            format!(
                "{{\"id\":98,\"op\":\"debug_delay_worker\",\"shard\":{shard},\
                 \"delay_ms\":{delay_ms}}}\n"
            )
            .as_bytes(),
        )
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"delayed\":1"), "got: {line}");
}

/// Fire `k` serial mvms with per-request fresh vectors, assert every
/// reply byte-identical to the reference model's direct MVM, and return
/// the client-side latency histogram.
fn serial_mvms(
    client: &mut Client,
    reference: &SimplexGp,
    k: usize,
    seed: u64,
    what: &str,
) -> LatencyHistogram {
    let n = reference.n_train();
    let mut rng = Pcg64::new(seed);
    let mut hist = LatencyHistogram::new();
    for i in 0..k {
        let v = rng.normal_vec(n);
        let direct = reference.operator().lattice.mvm(&v);
        let t0 = Instant::now();
        let u = client.mvm(&v).unwrap();
        hist.record(t0.elapsed().as_secs_f64() * 1e6);
        assert_bits_eq(&u, &direct, &format!("{what} request {i}"));
    }
    hist
}

/// The ISSUE 6 acceptance gate: with one injected-slow worker, turning
/// hedging on cuts p99 by at least 3× versus hedging off, while every
/// reply stays byte-identical to local compute — and the backup
/// replica, not the fallback, is what serves the hedged shard.
#[test]
fn hedging_cuts_p99_at_least_3x_with_byte_identical_replies() {
    let d = 2;
    let (x, y) = problem(240, d, 71);
    let reference = fit(&x, &y, d, 2);
    const DELAY_MS: u64 = 600;
    const HEDGE_MS: u64 = 30;
    const K: usize = 8;

    // Hedging OFF: every request waits out the slow worker.
    let workers_off = start_workers(2);
    let server_off = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: cluster_cfg(&workers_off, 0),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client_off = Client::connect(&server_off.local_addr).unwrap();
    wait_remote_synced(&mut client_off, 2);
    delay_worker(&server_off.local_addr, 0, DELAY_MS);
    let hist_off = serial_mvms(&mut client_off, &reference, K, 500, "hedge-off");
    let p99_off = hist_off.percentile(99.0);
    assert_eq!(server_off.hedged(), 0);
    assert_eq!(server_off.hedge_wins(), 0);
    server_off.shutdown();
    for w in workers_off {
        w.shutdown();
    }

    // Hedging ON: the same straggler, raced against the backup replica.
    let workers_on = start_workers(2);
    let server_on = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: cluster_cfg(&workers_on, HEDGE_MS),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client_on = Client::connect(&server_on.local_addr).unwrap();
    wait_remote_synced(&mut client_on, 2);
    delay_worker(&server_on.local_addr, 0, DELAY_MS);
    let hist_on = serial_mvms(&mut client_on, &reference, K, 500, "hedge-on");
    let p99_on = hist_on.percentile(99.0);

    // The slow worker really did cost the unhedged server its tail...
    assert!(
        p99_off >= (DELAY_MS as f64) * 1e3 * 0.9,
        "straggler never bit: p99_off = {:.1} ms",
        p99_off / 1e3
    );
    // ...and hedging bought it back: ≥ 3× (in practice ≈ 10-20×).
    assert!(
        p99_off >= 3.0 * p99_on,
        "hedging cut p99 only {:.2}x ({:.1} ms -> {:.1} ms)",
        p99_off / p99_on.max(1.0),
        p99_off / 1e3,
        p99_on / 1e3
    );
    // Hedges fired, and at least one was won by the BACKUP's reply
    // (not the in-thread fallback)...
    assert!(server_on.hedged() >= 1, "no hedge fired");
    assert!(server_on.hedge_wins() >= 1, "no hedge won by the backup");
    assert!(server_on.hedge_wins() <= server_on.hedged());
    // ...which the worker-side per-shard counters corroborate: shard
    // 0's jobs were answered from its backup replica on worker 1.
    assert!(
        workers_on[1].served_for(0) >= 1,
        "backup replica of shard 0 on worker 1 never served \
         (worker 1 shard counts: {:?})",
        workers_on[1].held_shards()
    );
    server_on.shutdown();
    for w in workers_on {
        w.shutdown();
    }
}

/// The hedge fires at `hedge_ms`, not at `result_timeout`: with a 10 s
/// result timeout (the default) and a 1.5 s straggler, a hedged request
/// completes in well under a second.
#[test]
fn hedge_fires_without_waiting_out_result_timeout() {
    let d = 2;
    let (x, y) = problem(220, d, 73);
    let reference = fit(&x, &y, d, 2);
    let workers = start_workers(2);
    let cluster = cluster_cfg(&workers, 30);
    assert_eq!(cluster.result_timeout, Duration::from_secs(10));
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);
    delay_worker(&server.local_addr, 0, 1500);

    let mut rng = Pcg64::new(510);
    let v = rng.normal_vec(reference.n_train());
    let direct = reference.operator().lattice.mvm(&v);
    let t0 = Instant::now();
    let u = client.mvm(&v).unwrap();
    let elapsed = t0.elapsed();
    assert_bits_eq(&u, &direct, "hedged mvm");
    assert!(
        elapsed < Duration::from_millis(1000),
        "hedge did not fire early: {elapsed:?} (delay 1.5s, timeout 10s)"
    );
    assert!(server.hedged() >= 1);
    assert!(server.hedge_wins() >= 1);
    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// A hedge-winning backup must not corrupt the batcher's bookkeeping:
/// later requests (including after the straggler is cleared) still get
/// byte-identical replies, counters stay coherent, and the stale
/// primary replies that eventually arrive are discarded silently.
#[test]
fn hedge_winner_leaves_stats_and_bookkeeping_coherent() {
    let d = 2;
    let (x, y) = problem(230, d, 77);
    let reference = fit(&x, &y, d, 2);
    let workers = start_workers(2);
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: cluster_cfg(&workers, 25),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);

    delay_worker(&server.local_addr, 0, 400);
    serial_mvms(&mut client, &reference, 4, 520, "while-slow");
    // Clear the straggler (delay_ms 0) and keep going: the batcher must
    // still route, discard the earlier losers, and reply bit-exactly.
    delay_worker(&server.local_addr, 0, 0);
    serial_mvms(&mut client, &reference, 4, 530, "after-clear");

    let stats = client.stats().unwrap();
    let served = stats.get("served").and_then(|v| v.as_f64()).unwrap();
    let hedged = stats.get("hedged").and_then(|v| v.as_f64()).unwrap();
    let wins = stats.get("hedge_wins").and_then(|v| v.as_f64()).unwrap();
    let p50 = stats.get("p50_us").and_then(|v| v.as_f64()).unwrap();
    let p99 = stats.get("p99_us").and_then(|v| v.as_f64()).unwrap();
    assert!(served >= 8.0, "served={served}");
    assert!(hedged >= 1.0, "hedged={hedged}");
    assert!(wins <= hedged, "hedge_wins={wins} > hedged={hedged}");
    assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
    assert_eq!(server.hedged(), hedged as u64);
    assert_eq!(server.hedge_wins(), wins as u64);
    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// `hedge_ms = 0` (the default) reproduces PR 5 behavior bitwise: the
/// slow worker is waited out, no backup replicas serve, and the hedging
/// counters stay pinned at zero.
#[test]
fn hedging_off_reproduces_unhedged_behavior() {
    let d = 2;
    let (x, y) = problem(210, d, 79);
    let reference = fit(&x, &y, d, 2);
    let workers = start_workers(2);
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: cluster_cfg(&workers, 0),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    wait_remote_synced(&mut client, 2);
    delay_worker(&server.local_addr, 0, 250);

    let mut rng = Pcg64::new(540);
    for i in 0..2 {
        let v = rng.normal_vec(reference.n_train());
        let direct = reference.operator().lattice.mvm(&v);
        let t0 = Instant::now();
        let u = client.mvm(&v).unwrap();
        // Unhedged: the request waits the straggler out.
        assert!(
            t0.elapsed() >= Duration::from_millis(200),
            "request {i} did not wait for the delayed worker"
        );
        assert_bits_eq(&u, &direct, &format!("unhedged request {i}"));
    }
    assert_eq!(server.hedged(), 0);
    assert_eq!(server.hedge_wins(), 0);
    // Without hedging no worker holds a backup replica: round-robin
    // assignment only, one shard each.
    assert_eq!(workers[0].held_shards(), vec![0]);
    assert_eq!(workers[1].held_shards(), vec![1]);
    assert_eq!(workers[1].served_for(0), 0);
    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// The in-process pool hedges too: with no backup workers the hedge IS
/// the in-thread fallback, fired at `hedge_ms` instead of waiting for
/// `result_timeout`. `hedge_wins` stays 0 — the fallback is not a
/// backup reply.
#[test]
fn local_pool_hedges_to_in_thread_fallback() {
    let d = 2;
    let (x, y) = problem(220, d, 83);
    let reference = fit(&x, &y, d, 2);
    let server = Server::start(
        fit(&x, &y, d, 2),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            debug_ops: true,
            cluster: ClusterConfig {
                hedge: Some(Duration::from_millis(30)),
                ..ClusterConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    delay_worker(&server.local_addr, 0, 700);

    let mut rng = Pcg64::new(550);
    let v = rng.normal_vec(reference.n_train());
    let direct = reference.operator().lattice.mvm(&v);
    let t0 = Instant::now();
    let u = client.mvm(&v).unwrap();
    let elapsed = t0.elapsed();
    assert_bits_eq(&u, &direct, "local hedged mvm");
    assert!(
        elapsed < Duration::from_millis(500),
        "local hedge did not fire early: {elapsed:?} (delay 700ms)"
    );
    assert!(server.hedged() >= 1, "no local hedge fired");
    assert_eq!(
        server.hedge_wins(),
        0,
        "the in-thread fallback must not count as a backup win"
    );
    server.shutdown();
}

//! Seeded-random property harness: ONE place asserting the invariants
//! the stack's correctness rests on, swept over the cross product
//! d ∈ {2, 5, 9} × shards P ∈ {1, 3} × batch B ∈ {1, 7} × kernel
//! families — configurations the ad-hoc suites only spot-check.
//!
//! Invariants (per ISSUE 4):
//! - **MVM symmetry**: ⟨u, K̃v⟩ = ⟨K̃u, v⟩ on the symmetrized operator.
//! - **PSD-ness**: Lanczos Ritz values of K̃ stay ≥ −1e-8 (relative to
//!   the top Ritz value) — the Krylov solvers' working assumption.
//! - **Batch/single equivalence**: `mvm_block(·, B)` row c equals
//!   `mvm` on RHS c, ≤ 1e-12 (the per-RHS arithmetic is identical).
//! - **Shard/single equivalence**: shard p's output rows equal a
//!   standalone lattice built on shard p's points, ≤ 1e-12.
//! - **Ingest-vs-rebuild bit equality**: streaming points into a built
//!   lattice yields the same arrays — and bitwise-identical MVMs — as a
//!   from-scratch build at the final point set.
//! - **Concurrent-load determinism** (ISSUE 6): mvm traffic raced
//!   against streaming ingest through the serving coordinator, fired
//!   per an open-loop load schedule, is bitwise explainable by a
//!   serial replay on a twin model.
//! - **Warm-start equivalence** (ISSUE 9): `x0 = None` through the
//!   warm-started CG entry point is the cold path bit for bit; an
//!   exact-solution seed converges in ≤ 1 iteration; a warm-seeded
//!   ingest re-solve matches the cold re-solve of the same patched
//!   operator to ≤ 1e-10 in strictly fewer iterations; and the block
//!   solver's per-RHS freeze contract survives a nonzero guess.
//!
//! All randomness flows through the crate's own seeded [`Pcg64`]
//! (no external dependencies); every case prints its parameters in the
//! assertion message so a failure is reproducible from the seed.

use std::time::{Duration, Instant};

use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::{PermutohedralLattice, ShardedLattice};
use simplex_gp::linalg::eigh_tridiag;
use simplex_gp::loadgen::{schedule, Arrival, Mix, OpKind};
use simplex_gp::mvm::{MvmOperator, ShardedMvm, Shifted};
use simplex_gp::solvers::{cg_block_precond, cg_block_precond_x0, lanczos, CgOptions};
use simplex_gp::util::stats::dot;
use simplex_gp::util::Pcg64;

const DIMS: [usize; 3] = [2, 5, 9];
const SHARDS: [usize; 2] = [1, 3];
const BATCHES: [usize; 2] = [1, 7];
const FAMILIES: [KernelFamily; 2] = [KernelFamily::Rbf, KernelFamily::Matern32];

/// One sweep configuration, with a seed derived from its coordinates so
/// every case is independently reproducible.
struct Case {
    d: usize,
    p: usize,
    b: usize,
    family: KernelFamily,
    seed: u64,
}

fn cases() -> Vec<Case> {
    let mut out = Vec::new();
    let mut idx = 0u64;
    for &d in &DIMS {
        for &p in &SHARDS {
            for &b in &BATCHES {
                for &family in &FAMILIES {
                    out.push(Case {
                        d,
                        p,
                        b,
                        family,
                        seed: 0xa11c_e000 + idx,
                    });
                    idx += 1;
                }
            }
        }
    }
    out
}

fn random_points(n: usize, d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::with_stream(0x16e5_7001, seed);
    rng.normal_vec(n * d)
}

#[test]
fn mvm_symmetry_across_the_sweep() {
    for c in cases() {
        let n = 150;
        let x = random_points(n, c.d, c.seed);
        let k = ArdKernel::with_lengthscale(c.family, c.d, 1.0);
        let op = ShardedMvm::build(&x, c.d, &k, 1, c.p).with_symmetrize(true);
        let mut rng = Pcg64::with_stream(0x5e11, c.seed);
        let u = rng.normal_vec(n);
        let v = rng.normal_vec(n);
        let a = dot(&u, &op.mvm(&v));
        let b = dot(&v, &op.mvm(&u));
        assert!(
            (a - b).abs() <= 1e-10 * (1.0 + a.abs().max(b.abs())),
            "case (d={} P={} {:?} seed={}): asymmetry {a} vs {b}",
            c.d,
            c.p,
            c.family,
            c.seed
        );
    }
}

#[test]
fn psd_via_lanczos_ritz_values_across_the_sweep() {
    // The Krylov solvers assume K̃ ⪰ 0 (up to rounding): every Ritz
    // value of a Lanczos run lies in the operator's numerical range, so
    // min-Ritz ≥ −1e-8·scale certifies no materially negative
    // directions were found.
    for c in cases() {
        let n = 150;
        let x = random_points(n, c.d, c.seed);
        let k = ArdKernel::with_lengthscale(c.family, c.d, 1.0);
        let op = ShardedMvm::build(&x, c.d, &k, 1, c.p).with_symmetrize(true);
        let mut rng = Pcg64::with_stream(0x9d, c.seed);
        let q0 = rng.normal_vec(n);
        let lr = lanczos(&op, &q0, 30, false);
        let (ritz, _) = eigh_tridiag(&lr.alpha, &lr.beta);
        let top = ritz.last().copied().unwrap_or(0.0).max(1.0);
        let bottom = ritz.first().copied().unwrap_or(0.0);
        assert!(
            bottom >= -1e-8 * top,
            "case (d={} P={} {:?} seed={}): min Ritz {bottom:.3e} (top {top:.3e})",
            c.d,
            c.p,
            c.family,
            c.seed
        );
    }
}

#[test]
fn batch_single_equivalence_across_the_sweep() {
    for c in cases() {
        let n = 120;
        let x = random_points(n, c.d, c.seed.wrapping_add(1));
        let mut k = ArdKernel::with_lengthscale(c.family, c.d, 0.9);
        k.outputscale = 1.4;
        for symmetrize in [false, true] {
            let op = ShardedMvm::build(&x, c.d, &k, 1, c.p).with_symmetrize(symmetrize);
            let mut rng = Pcg64::with_stream(0xba7c4, c.seed);
            let v = rng.normal_vec(n * c.b);
            let block = op.mvm_block(&v, c.b);
            for col in 0..c.b {
                let single = op.mvm(&v[col * n..(col + 1) * n]);
                for i in 0..n {
                    let (got, want) = (block[col * n + i], single[i]);
                    assert!(
                        (got - want).abs() <= 1e-12,
                        "case (d={} P={} B={} {:?} sym={symmetrize}) rhs {col} row {i}: \
                         {got} vs {want}",
                        c.d,
                        c.p,
                        c.b,
                        c.family
                    );
                }
            }
        }
    }
}

#[test]
fn shard_single_equivalence_across_the_sweep() {
    for c in cases() {
        if c.p == 1 {
            continue; // the P = 1 case IS the single lattice (below)
        }
        let n = 120;
        let x = random_points(n, c.d, c.seed.wrapping_add(2));
        let k = ArdKernel::with_lengthscale(c.family, c.d, 0.8);
        let sharded = ShardedLattice::build(&x, c.d, &k, 1, c.p);
        let mut rng = Pcg64::with_stream(0x54a2d, c.seed);
        let v = rng.normal_vec(n);
        let u = sharded.mvm(&v);
        for p in 0..c.p {
            let r = sharded.shard_range(p);
            let solo =
                PermutohedralLattice::build(&x[r.start * c.d..r.end * c.d], c.d, &k, 1);
            let us = solo.mvm(&v[r.clone()]);
            for (i, (got, want)) in u[r].iter().zip(&us).enumerate() {
                assert!(
                    (got - want).abs() <= 1e-12,
                    "case (d={} P={} {:?}) shard {p} row {i}: {got} vs {want}",
                    c.d,
                    c.p,
                    c.family
                );
            }
        }
    }
    // P = 1 leg: the sharded operator reproduces the single lattice.
    for &d in &DIMS {
        let n = 120;
        let x = random_points(n, d, 77);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.8);
        let sharded = ShardedLattice::build(&x, d, &k, 1, 1);
        let single = PermutohedralLattice::build(&x, d, &k, 1);
        let mut rng = Pcg64::with_stream(0x54a2e, d as u64);
        let v = rng.normal_vec(n);
        assert_eq!(sharded.mvm(&v), single.mvm(&v), "d={d}");
    }
}

#[test]
fn ingest_vs_rebuild_bit_equality_across_the_sweep() {
    // Stream the tail of each case's point set into a lattice built on
    // the head; every shard must be bit-identical to a from-scratch
    // build on its final point set, and the full MVM must match bitwise.
    for c in cases() {
        let n = 120;
        let batch_rows = 15;
        let x = random_points(n, c.d, c.seed.wrapping_add(3));
        let k = ArdKernel::with_lengthscale(c.family, c.d, 0.9);
        let base = n - 2 * batch_rows;
        let mut lat = ShardedLattice::build(&x[..base * c.d], c.d, &k, 1, c.p);
        // Track each shard's final point set while streaming.
        let mut shard_x: Vec<Vec<f64>> = (0..c.p)
            .map(|p| x[lat.bounds[p] * c.d..lat.bounds[p + 1] * c.d].to_vec())
            .collect();
        for step in 0..2 {
            let lo = (base + step * batch_rows) * c.d;
            let hi = lo + batch_rows * c.d;
            let out = lat.ingest(&x[lo..hi], &k);
            assert_eq!(out.rows, batch_rows);
            shard_x[out.shard].extend_from_slice(&x[lo..hi]);
        }
        assert_eq!(lat.n, n);
        let mut rng = Pcg64::with_stream(0x16e5, c.seed);
        for p in 0..c.p {
            let solo = PermutohedralLattice::build(&shard_x[p], c.d, &k, 1);
            assert_eq!(
                lat.shards[p].offsets, solo.offsets,
                "case (d={} P={} {:?}) shard {p} offsets",
                c.d, c.p, c.family
            );
            assert_eq!(lat.shards[p].neighbors, solo.neighbors);
            assert_eq!(lat.shards[p].m, solo.m);
            for (i, (a, b)) in lat.shards[p].weights.iter().zip(&solo.weights).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case (d={} P={} {:?}) shard {p} weight {i}",
                    c.d,
                    c.p,
                    c.family
                );
            }
            let v = rng.normal_vec(solo.n);
            let (ua, ub) = (lat.shards[p].mvm(&v), solo.mvm(&v));
            for i in 0..solo.n {
                assert_eq!(
                    ua[i].to_bits(),
                    ub[i].to_bits(),
                    "case (d={} P={} {:?}) shard {p} mvm row {i}",
                    c.d,
                    c.p,
                    c.family
                );
            }
        }
    }
}

#[test]
fn ingest_stream_bitwise_equals_rebuild_for_batches_1_64_1024() {
    // The ISSUE-4 acceptance pin: streaming n points in batches of
    // k ∈ {1, 64, 1024} yields MVMs bitwise-equal to a from-scratch
    // lattice build at the final point set.
    let d = 4;
    let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.7);
    for &(n_total, batch) in &[(400usize, 1usize), (1000, 64), (2100, 1024)] {
        let x = random_points(n_total, d, 1000 + batch as u64);
        let base = 128;
        let mut inc = PermutohedralLattice::build(&x[..base * d], d, &k, 1);
        let mut at = base;
        while at < n_total {
            let hi = (at + batch).min(n_total);
            inc.ingest(&x[at * d..hi * d], &k);
            at = hi;
        }
        let full = PermutohedralLattice::build(&x, d, &k, 1);
        assert_eq!(inc.n, full.n, "batch {batch}");
        assert_eq!(inc.m, full.m, "batch {batch}");
        assert_eq!(inc.offsets, full.offsets, "batch {batch}");
        assert_eq!(inc.neighbors, full.neighbors, "batch {batch}");
        for (i, (a, b)) in inc.weights.iter().zip(&full.weights).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "batch {batch} weight {i}");
        }
        let mut rng = Pcg64::with_stream(0xacce7, batch as u64);
        let v = rng.normal_vec(n_total);
        let (ui, uf) = (inc.mvm(&v), full.mvm(&v));
        for i in 0..n_total {
            assert_eq!(ui[i].to_bits(), uf[i].to_bits(), "batch {batch} row {i}");
        }
    }
}

/// Shared body for the concurrent-load determinism legs. With
/// `shed = false` this is the PR-6 in-process-pool shape; with
/// `shed = true` the coordinator runs `[cluster] shed_shards` against
/// two loopback shard workers, so the same race — coalesced mvms
/// against streaming ingest — rides the fully worker-resident path
/// (remote replicas, synchronous replica patches, routed α solves) and
/// must STILL be bitwise explainable by the serial unshed twin replay,
/// with zero on-demand rebuilds on the healthy fleet.
fn concurrent_load_case(shed: bool) {
    use simplex_gp::coordinator::transport::ClusterConfig;
    use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};

    let d = 2;
    let shards = 2;
    let n0 = 200;
    let x = random_points(n0, d, 0x6001);
    let mut yrng = Pcg64::with_stream(0x6002, 1);
    let y: Vec<f64> = (0..n0)
        .map(|i| x[i * d].sin() + 0.05 * yrng.normal())
        .collect();
    let fit = |x: &[f64], y: &[f64]| {
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
        let cfg = GpConfig {
            shards,
            ..GpConfig::default()
        };
        SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
    };
    let mut twin = fit(&x, &y);
    let workers: Vec<ShardWorker> = if shed {
        (0..2)
            .map(|_| {
                ShardWorker::start(WorkerConfig {
                    listen: "127.0.0.1:0".to_string(),
                    ..WorkerConfig::default()
                })
                .unwrap()
            })
            .collect()
    } else {
        Vec::new()
    };
    let cluster = ClusterConfig {
        workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
        shed_shards: shed,
        ..ClusterConfig::default()
    };
    let server = Server::start(
        fit(&x, &y),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            allow_ingest: true,
            // Generous coalescing window: concurrent mvms really do
            // share batches instead of degenerating to serial service.
            max_wait: Duration::from_millis(20),
            cluster,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    if shed {
        // Replicas sync in the background; wait for the fleet before
        // opening the load (the measurement is about the shed steady
        // state, not the warmup fallback).
        let mut probe = Client::connect(&server.local_addr).unwrap();
        let t0 = Instant::now();
        loop {
            let st = probe.stats().unwrap();
            let up = st
                .get("remote_workers")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as usize;
            if up == 2 {
                assert_eq!(
                    st.get("shed_shards").and_then(|v| v.as_f64()),
                    Some(shards as f64),
                    "shards not shed at pool start"
                );
                break;
            }
            assert!(
                t0.elapsed().as_secs() < 30,
                "loopback shard workers never synced"
            );
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    // Phases = the schedule's mvm arrivals between consecutive ingest
    // arrivals (predict weight 0: only mvm replies are byte-checkable).
    let plan = schedule(
        Arrival::Bursty {
            period: Duration::from_millis(120),
            on_fraction: 0.4,
        },
        260.0,
        Duration::from_secs(1),
        Mix {
            predict: 0.0,
            mvm: 0.85,
            ingest: 0.15,
        },
        0x5eed,
    );
    let mut phases: Vec<Vec<Duration>> = vec![Vec::new()];
    for p in &plan {
        match p.kind {
            OpKind::Mvm => phases.last_mut().unwrap().push(p.at),
            OpKind::Ingest => phases.push(Vec::new()),
            OpKind::Predict => {}
        }
    }
    phases.truncate(5);

    const MAX_CONC: usize = 8;
    let mut clients: Vec<Client> = (0..MAX_CONC)
        .map(|_| Client::connect(&server.local_addr).unwrap())
        .collect();
    let mut ingest_rng = Pcg64::with_stream(0x6003, 9);
    let mut total_mvms = 0usize;

    for (pi, offsets) in phases.iter().enumerate() {
        let n = twin.n_train();
        let m = offsets.len().clamp(1, MAX_CONC);
        let vs: Vec<Vec<f64>> = (0..m)
            .map(|j| Pcg64::with_stream(0x6004, (pi * 100 + j) as u64).normal_vec(n))
            .collect();
        let base = offsets.first().copied().unwrap_or(Duration::ZERO);
        let epoch = Instant::now();
        let replies: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = clients[..m]
                .iter_mut()
                .zip(vs.iter())
                .enumerate()
                .map(|(j, (client, v))| {
                    let at = offsets
                        .get(j)
                        .copied()
                        .unwrap_or(base)
                        .saturating_sub(base);
                    s.spawn(move || {
                        let sched = epoch + at;
                        let now = Instant::now();
                        if sched > now {
                            std::thread::sleep(sched - now);
                        }
                        client.mvm(v).unwrap()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("load client thread panicked"))
                .collect()
        });
        for (j, (got, v)) in replies.iter().zip(&vs).enumerate() {
            let want = twin.operator().lattice.mvm(v);
            assert_eq!(got.len(), want.len(), "phase {pi} mvm {j}: length");
            for i in 0..want.len() {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "phase {pi} mvm {j} row {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
        total_mvms += m;

        // Phase barrier: one scheduled ingest, applied to both models.
        let rows = 4;
        let xi: Vec<f64> = (0..rows * d)
            .map(|_| ingest_rng.uniform_in(-2.0, 2.0))
            .collect();
        let yi: Vec<f64> = (0..rows).map(|_| ingest_rng.normal()).collect();
        let n_live = clients[0].ingest(&xi, &yi, d).unwrap();
        twin.ingest(&xi, &yi).unwrap();
        assert_eq!(n_live, twin.n_train(), "phase {pi}: ingest diverged");
    }
    assert!(
        total_mvms >= 5,
        "schedule produced too little concurrent traffic: {total_mvms} mvms"
    );

    // Closing cross-check at the final (grown) point set.
    let v = Pcg64::with_stream(0x6005, 3).normal_vec(twin.n_train());
    let want = twin.operator().lattice.mvm(&v);
    let got = clients[0].mvm(&v).unwrap();
    for i in 0..want.len() {
        assert_eq!(got[i].to_bits(), want[i].to_bits(), "final mvm row {i}");
    }
    if shed {
        // The whole race was served worker-resident: the healthy fleet
        // never forced an on-demand rebuild, and every shard is still
        // shed after the last ingest barrier.
        assert_eq!(server.shed_rebuilds(), 0, "healthy fleet forced rebuilds");
        let st = clients[0].stats().unwrap();
        assert_eq!(
            st.get("shed_shards").and_then(|v| v.as_f64()),
            Some(shards as f64),
            "ingest left shards resident"
        );
    }
    server.shutdown();
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn concurrent_load_bitwise_matches_serial_replay() {
    // ISSUE-6 leg: mvm traffic raced against streaming ingest through
    // the serving coordinator must be bitwise explainable by a serial
    // replay on a twin model. The op sequence and fire times come from
    // the open-loop load schedule; each segment between two scheduled
    // ingests holds n fixed, so every concurrent mvm inside it has
    // exactly one right answer no matter how the batcher coalesces or
    // interleaves — the ingest then acts as a barrier and mutates the
    // served model and the twin identically.
    concurrent_load_case(false);
}

#[test]
fn concurrent_load_with_shed_shards_bitwise_matches_serial_replay() {
    // PR-8 leg: the same schedule with the coordinator fully shed
    // behind two loopback workers — worker-resident serving changes
    // where the arithmetic runs, never what it produces.
    concurrent_load_case(true);
}

// ---------------------------------------------------------------------
// Warm-start invariants (ISSUE 9). The unit-level pins live next to the
// solver (solvers/cg.rs); these legs run the SAME contracts on the real
// sharded lattice operator across the sweep, where the block MVM is a
// genuine splat→blur→slice pass.
// ---------------------------------------------------------------------

#[test]
fn warm_x0_none_bitwise_equals_cold_path_across_the_sweep() {
    // `cg_block_precond_x0(.., None)` must reproduce `cg_block_precond`
    // exactly — the None branch IS the old code (delegation), so every
    // pre-warm-start caller keeps its bytes. Pinned on the lattice
    // operator so a future "optimization" of the shared loop that
    // perturbs the cold FP sequence fails loudly here.
    for c in cases() {
        if c.b != 1 {
            continue; // nrhs is swept explicitly below
        }
        let n = 140;
        let x = random_points(n, c.d, c.seed.wrapping_add(4));
        let k = ArdKernel::with_lengthscale(c.family, c.d, 1.0);
        let op = ShardedMvm::build(&x, c.d, &k, 1, c.p).with_symmetrize(true);
        let shifted = Shifted::new(&op, 0.5);
        let mut rng = Pcg64::with_stream(0x9a12, c.seed);
        for nrhs in [1usize, 3] {
            let b = rng.normal_vec(n * nrhs);
            let opts = CgOptions {
                tol: 1e-8,
                max_iters: 200,
                min_iters: 1,
            };
            let cold = cg_block_precond(&shifted, &b, nrhs, opts, None);
            let via_x0 = cg_block_precond_x0(&shifted, &b, nrhs, opts, None, None);
            assert_eq!(
                cold.x, via_x0.x,
                "case (d={} P={} {:?}) nrhs={nrhs}: x0=None drifted",
                c.d, c.p, c.family
            );
            assert_eq!(cold.iterations, via_x0.iterations);
            assert_eq!(cold.rhs_iterations, via_x0.rhs_iterations);
            assert_eq!(cold.rms_residual, via_x0.rms_residual);
        }
    }
}

#[test]
fn exact_seed_converges_in_at_most_one_iteration_across_the_sweep() {
    // Seeding with the (tightly solved) solution leaves a residual an
    // order of magnitude under the warm tolerance, so the warm solve
    // freezes at the first convergence check: ≤ 1 iteration.
    for c in cases() {
        if c.b != 1 {
            continue;
        }
        let n = 140;
        let x = random_points(n, c.d, c.seed.wrapping_add(5));
        let k = ArdKernel::with_lengthscale(c.family, c.d, 1.0);
        let op = ShardedMvm::build(&x, c.d, &k, 1, c.p).with_symmetrize(true);
        let shifted = Shifted::new(&op, 0.5);
        let mut rng = Pcg64::with_stream(0x9a13, c.seed);
        let b = rng.normal_vec(n);
        let tight = CgOptions {
            tol: 1e-11,
            max_iters: 500,
            min_iters: 1,
        };
        let cold = cg_block_precond(&shifted, &b, 1, tight, None);
        assert!(
            cold.converged.iter().all(|&ok| ok),
            "case (d={} P={} {:?}): cold solve did not converge",
            c.d,
            c.p,
            c.family
        );
        let warm_opts = CgOptions {
            tol: 1e-10,
            max_iters: 500,
            min_iters: 1,
        };
        let warm = cg_block_precond_x0(&shifted, &b, 1, warm_opts, None, Some(&cold.x));
        assert!(
            warm.iterations <= 1,
            "case (d={} P={} {:?}): exact seed took {} iterations",
            c.d,
            c.p,
            c.family,
            warm.iterations
        );
        assert!(warm.converged.iter().all(|&ok| ok));
        for (w, s) in warm.x.iter().zip(&cold.x) {
            assert!(
                (w - s).abs() <= 1e-8,
                "case (d={} P={} {:?}): exact-seed solve moved",
                c.d,
                c.p,
                c.family
            );
        }
    }
}

#[test]
fn warm_ingest_matches_cold_resolve_with_fewer_iterations() {
    // The streaming contract (ISSUE 9 acceptance): after an ingest, the
    // warm re-solve — seeded with the previous α, zeros spliced over
    // the new rows — must land on the cold re-solve of the SAME patched
    // operator to ≤ 1e-10, in strictly fewer CG iterations. The cold
    // comparator is a bitwise-identical twin (same deterministic fit,
    // same patch) whose α is re-solved unseeded, so the two solves
    // differ in nothing but the initial guess.
    for &d in &[2usize, 5] {
        for &p in &[1usize, 3] {
            for &family in &FAMILIES {
                let n0 = 160;
                let rows = 6;
                let seed = 0x9a14 + (d * 10 + p) as u64;
                let x = random_points(n0 + rows, d, seed);
                let mut yrng = Pcg64::with_stream(0x9a15, seed);
                let y: Vec<f64> = (0..n0 + rows)
                    .map(|i| x[i * d].sin() + 0.1 * yrng.normal())
                    .collect();
                let kernel = ArdKernel::with_lengthscale(family, d, 0.8);
                let cfg = GpConfig {
                    shards: p,
                    precond_rank: 16,
                    cg_tol: 1e-12,
                    ..GpConfig::default()
                };
                // λ_min(K̃+σ²I) ≥ σ² = 0.5 turns the 1e-12 residual
                // tolerance into a guaranteed ≤ ~5e-11 bound on |Δα|.
                let noise = 0.5;
                let fit = || {
                    SimplexGp::fit(&x[..n0 * d], &y[..n0], d, kernel.clone(), noise, cfg.clone())
                        .unwrap()
                };
                let mut warm = fit();
                let mut cold = fit();
                assert_eq!(warm.alpha(), cold.alpha(), "twin fits diverged");

                let (xb, yb) = (&x[n0 * d..], &y[n0..]);
                warm.ingest(xb, yb).unwrap();
                cold.ingest_patch(xb, yb).unwrap();
                cold.resolve_alpha();

                let tag = format!("d={d} P={p} {family:?}");
                assert!(warm.last_solve_warm(), "{tag}: ingest solve not warm");
                assert!(!cold.last_solve_warm(), "{tag}: comparator not cold");
                assert!(
                    warm.fit_iterations < cold.fit_iterations,
                    "{tag}: warm {} vs cold {} iterations",
                    warm.fit_iterations,
                    cold.fit_iterations
                );
                assert_eq!(warm.alpha().len(), cold.alpha().len(), "{tag}");
                for (i, (a, b)) in warm.alpha().iter().zip(cold.alpha()).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-10,
                        "{tag} α row {i}: warm {a} vs cold {b}"
                    );
                }
                let xq = random_points(5, d, seed ^ 0xdead_beef);
                let (mw, vw) = warm.predict(&xq);
                let (mc, vc) = cold.predict(&xq);
                for i in 0..mw.len() {
                    assert!((mw[i] - mc[i]).abs() <= 1e-10, "{tag} mean {i}");
                    assert!((vw[i] - vc[i]).abs() <= 1e-8, "{tag} var {i}");
                }
            }
        }
    }
}

#[test]
fn per_rhs_freeze_preserved_under_nonzero_guess() {
    // Mixed warm/cold blocks: an exactly-seeded column freezes at the
    // first check and stays frozen while its neighbors keep iterating;
    // a zero-seeded column behaves like a cold solve of that column.
    let (d, p, n) = (3usize, 2usize, 140usize);
    let x = random_points(n, d, 0x9a16);
    let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let op = ShardedMvm::build(&x, d, &k, 1, p).with_symmetrize(true);
    let shifted = Shifted::new(&op, 0.5);
    let mut rng = Pcg64::with_stream(0x9a17, 1);
    let nrhs = 3;
    let b = rng.normal_vec(n * nrhs);

    // Column 0's exact solution, solved an order tighter than the
    // block tolerance below.
    let tight = CgOptions {
        tol: 1e-11,
        max_iters: 500,
        min_iters: 1,
    };
    let x0_exact = cg_block_precond(&shifted, &b[..n], 1, tight, None);
    assert!(x0_exact.converged[0]);

    let opts = CgOptions {
        tol: 1e-10,
        max_iters: 500,
        min_iters: 1,
    };
    // Seed block: col 0 = exact solution, col 1 = zeros (cold), col 2 =
    // a nonzero perturbation of nothing in particular.
    let mut seed = vec![0.0; n * nrhs];
    seed[..n].copy_from_slice(&x0_exact.x);
    for v in seed[2 * n..].iter_mut() {
        *v = 0.01 * rng.normal();
    }
    let mixed = cg_block_precond_x0(&shifted, &b, nrhs, opts, None, Some(&seed));

    // Col 0 froze immediately and its iterate never moved materially.
    assert!(
        mixed.rhs_iterations[0] <= 1,
        "exact-seeded column ran {} iterations",
        mixed.rhs_iterations[0]
    );
    for i in 0..n {
        assert!(
            (mixed.x[i] - x0_exact.x[i]).abs() <= 1e-8,
            "frozen column drifted at row {i}"
        );
    }
    // Its neighbors kept iterating to convergence — the freeze is per
    // RHS, not global.
    assert!(mixed.converged.iter().all(|&ok| ok));
    assert!(
        mixed.rhs_iterations[1] > mixed.rhs_iterations[0],
        "cold column {} vs frozen column {}",
        mixed.rhs_iterations[1],
        mixed.rhs_iterations[0]
    );
    assert_eq!(
        mixed.iterations,
        *mixed.rhs_iterations.iter().max().unwrap(),
        "shared loop length is the slowest RHS"
    );
    // The zero-seeded column matches a cold single-RHS solve of the
    // same column (per-column independence under a mixed guess).
    let cold1 = cg_block_precond(&shifted, &b[n..2 * n], 1, opts, None);
    for i in 0..n {
        assert!(
            (mixed.x[n + i] - cold1.x[i]).abs() <= 1e-8,
            "zero-seeded column diverged from cold at row {i}"
        );
    }
}

//! End-to-end equivalence tests for the preconditioned solve path
//! (PR 3): preconditioned block-CG must reach the unpreconditioned
//! solution in strictly fewer iterations, the sharded preconditioner
//! must be bit-identical to the single-factor one at P = 1 and exactly
//! block-diagonal at P > 1, and rank = 0 must reproduce the existing
//! unpreconditioned path bit for bit.

use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{DenseMvm, ShardedMvm, Shifted};
use simplex_gp::solvers::{
    cg, cg_block, cg_block_precond, CgOptions, ExactKernelRows, PivCholPrecond, Precond,
};
use simplex_gp::util::stats::rmse;
use simplex_gp::util::Pcg64;

/// A smooth noisy target on [-2, 2]^d.
fn toy_problem(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let row = &x[i * d..(i + 1) * d];
            let s: f64 = row.iter().map(|v| (1.3 * v).sin()).sum();
            s + 0.05 * rng.normal()
        })
        .collect();
    (x, y)
}

#[test]
fn block_pcg_matches_unpreconditioned_solution_with_fewer_iterations() {
    // Ill-conditioned dense system: smooth RBF kernel + small noise
    // (cond ≈ n·s²/σ² = 2.5e3). Preconditioned block-CG must agree with
    // the unpreconditioned solution to ≤ 1e-8 per entry and take
    // strictly fewer Krylov iterations.
    let d = 2;
    let n = 250;
    let mut rng = Pcg64::new(1);
    let x = rng.normal_vec(n * d);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.5);
    let sigma2 = 0.1;
    let mut km = kernel.cov_matrix(&x, d);
    km.add_diag(sigma2);
    let op = DenseMvm { mat: km };
    let nrhs = 3;
    let b = rng.normal_vec(n * nrhs);
    let opts = CgOptions {
        tol: 1e-11,
        max_iters: 1000,
        min_iters: 1,
    };
    let plain = cg_block(&op, &b, nrhs, opts);
    let pc = PivCholPrecond::build(&ExactKernelRows { kernel: &kernel, x: &x, d }, 60, sigma2);
    let pre = cg_block_precond(&op, &b, nrhs, opts, Some(&pc as &dyn Precond));
    assert!(
        pre.iterations < plain.iterations,
        "preconditioning did not cut iterations: {} vs {}",
        pre.iterations,
        plain.iterations
    );
    for c in 0..nrhs {
        assert!(plain.converged[c], "unpreconditioned rhs {c} did not converge");
        assert!(pre.converged[c], "preconditioned rhs {c} did not converge");
        assert!(
            pre.rhs_iterations[c] <= plain.rhs_iterations[c],
            "rhs {c}: pre {} vs plain {}",
            pre.rhs_iterations[c],
            plain.rhs_iterations[c]
        );
        for i in 0..n {
            let diff = (pre.x[c * n + i] - plain.x[c * n + i]).abs();
            assert!(diff <= 1e-8, "rhs {c} row {i}: |dx| = {diff:.3e}");
        }
    }
}

#[test]
fn sharded_precond_at_p1_matches_pivchol_bitwise() {
    // One shard spanning all rows runs the identical build arithmetic,
    // so factors and applications agree bit for bit — including when
    // the bounds come from a real ShardedLattice partition.
    let d = 3;
    let (x, _) = toy_problem(120, d, 2);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 0.8);
    let sigma2 = 0.05;
    let rank = 30;
    let op = ShardedMvm::build(&x, d, &kernel, 1, 1);
    assert_eq!(op.shard_bounds(), &[0, 120]);
    let sharded = op.build_precond(&x, &kernel, rank, sigma2);
    let single = PivCholPrecond::build(
        &ExactKernelRows { kernel: &kernel, x: &x, d },
        rank,
        sigma2,
    );
    assert_eq!(sharded.shard_count(), 1);
    assert_eq!(sharded.parts[0].pivots, single.pivots);
    assert_eq!(sharded.parts[0].l.data, single.l.data);
    let mut rng = Pcg64::new(3);
    for _ in 0..3 {
        let v = rng.normal_vec(120);
        assert_eq!(sharded.apply(&v), single.solve(&v));
    }
}

#[test]
fn sharded_precond_is_block_diagonal_over_the_operator_partition() {
    // P = 3: applying the sharded preconditioner equals applying each
    // shard's factor to that shard's row segment, bit for bit — the
    // same block structure the sharded operator itself has.
    let d = 2;
    let n = 150;
    let (x, _) = toy_problem(n, d, 4);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let sigma2 = 0.02;
    let rank = 20;
    let op = ShardedMvm::build(&x, d, &kernel, 1, 3);
    let bounds = op.shard_bounds().to_vec();
    let pc = op.build_precond(&x, &kernel, rank, sigma2);
    let mut rng = Pcg64::new(5);
    let v = rng.normal_vec(n);
    let got = pc.apply(&v);
    for p in 0..3 {
        let (s0, s1) = (bounds[p], bounds[p + 1]);
        let solo = PivCholPrecond::build(
            &ExactKernelRows {
                kernel: &kernel,
                x: &x[s0 * d..s1 * d],
                d,
            },
            rank,
            sigma2,
        );
        assert_eq!(&got[s0..s1], solo.solve(&v[s0..s1]).as_slice(), "shard {p}");
    }
}

#[test]
fn rank0_fit_is_bit_identical_to_the_unpreconditioned_path() {
    // precond_rank = 0 must leave the fit on the exact same arithmetic
    // as a manual single-RHS CG on the shifted sharded operator, and
    // cg_block_precond(None) must be cg_block exactly.
    let d = 2;
    let (x, y) = toy_problem(300, d, 6);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.7);
    let noise = 0.05;
    let cfg = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    assert_eq!(cfg.precond_rank, 0, "default must be unpreconditioned");
    let gp = SimplexGp::fit(&x, &y, d, kernel.clone(), noise, cfg.clone()).unwrap();
    let op = ShardedMvm::build(&x, d, &kernel, cfg.order, cfg.shards)
        .with_symmetrize(cfg.symmetrize);
    let shifted = Shifted::new(&op, noise);
    let opts = CgOptions {
        tol: cfg.cg_tol,
        max_iters: cfg.cg_max_iters,
        min_iters: 1,
    };
    let manual = cg(&shifted, &y, opts);
    assert_eq!(gp.alpha(), manual.x.as_slice(), "rank-0 fit drifted from plain CG");
    assert_eq!(gp.fit_iterations, manual.iterations);

    // Solver-level contract: None is the same code path as cg_block.
    let mut rng = Pcg64::new(7);
    let nrhs = 3;
    let b = rng.normal_vec(300 * nrhs);
    let blk = cg_block(&shifted, &b, nrhs, opts);
    let none = cg_block_precond(&shifted, &b, nrhs, opts, None);
    assert_eq!(blk.x, none.x);
    assert_eq!(blk.rhs_iterations, none.rhs_iterations);
    assert_eq!(blk.rms_residual, none.rms_residual);
}

#[test]
fn preconditioned_fit_cuts_iterations_on_the_lattice_operator() {
    // The production path: SimplexGp::fit on the (symmetrized) lattice
    // operator with small noise. The rank-k factor of the *exact*
    // kernel must still precondition the lattice approximation — the
    // lattice error is relative to the kernel, so the preconditioned
    // spectrum stays clustered.
    let d = 2;
    let (x, y) = toy_problem(400, d, 8);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.5);
    let noise = 2e-2;
    let base_cfg = GpConfig {
        cg_tol: 1e-7,
        ..GpConfig::default()
    };
    let plain = SimplexGp::fit(&x, &y, d, kernel.clone(), noise, base_cfg.clone()).unwrap();
    let pre_cfg = GpConfig {
        precond_rank: 80,
        ..base_cfg
    };
    let pre = SimplexGp::fit(&x, &y, d, kernel, noise, pre_cfg).unwrap();
    assert_eq!(pre.precond_rank(), 80);
    assert!(
        pre.fit_iterations < plain.fit_iterations,
        "preconditioned fit {} iters vs plain {}",
        pre.fit_iterations,
        plain.fit_iterations
    );
    // Both solved the same system tightly: predictions must agree.
    let (xt, _) = toy_problem(60, d, 9);
    let a = plain.predict_mean(&xt);
    let b = pre.predict_mean(&xt);
    let err = rmse(&a, &b);
    assert!(err < 2e-2, "preconditioned predictions drifted: rmse {err}");
    // The variance path (preconditioned block-CG over test columns)
    // stays sane.
    let (_, var) = pre.predict(&xt[..10 * d]);
    for v in var {
        assert!(v.is_finite() && v > 0.0);
    }
}

#[test]
fn per_shard_precond_cuts_iterations_on_the_sharded_operator() {
    // P = 2: the block-diagonal preconditioner is structurally exact
    // for the block-diagonal sharded operator — iteration counts must
    // drop on the shifted sharded solve, and solutions must agree.
    let d = 2;
    let (x, _) = toy_problem(360, d, 10);
    let n = 360;
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.5);
    let sigma2 = 1e-2;
    let op = ShardedMvm::build(&x, d, &kernel, 1, 2).with_symmetrize(true);
    let shifted = Shifted::new(&op, sigma2);
    let mut rng = Pcg64::new(11);
    let nrhs = 3;
    let b = rng.normal_vec(n * nrhs);
    let opts = CgOptions {
        tol: 1e-7,
        max_iters: 500,
        min_iters: 1,
    };
    let plain = cg_block(&shifted, &b, nrhs, opts);
    let pc = op.build_precond(&x, &kernel, 60, sigma2);
    assert_eq!(pc.shard_count(), 2);
    let pre = cg_block_precond(&shifted, &b, nrhs, opts, Some(&pc as &dyn Precond));
    assert!(
        pre.iterations < plain.iterations,
        "sharded preconditioning did not cut iterations: {} vs {}",
        pre.iterations,
        plain.iterations
    );
    for c in 0..nrhs {
        for i in 0..n {
            let diff = (pre.x[c * n + i] - plain.x[c * n + i]).abs();
            assert!(diff < 1e-4, "rhs {c} row {i}: |dx| = {diff:.3e}");
        }
    }
}

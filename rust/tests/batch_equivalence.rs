//! Equivalence guarantees of the batched multi-RHS engine: for every
//! operator and solver, the `b × n` block path must reproduce the
//! single-RHS path to floating-point noise (the block engine reorders
//! no per-RHS arithmetic — it only amortizes traversals), and block-CG
//! must freeze each RHS at exactly the iteration sequential CG would
//! stop at. Hand-rolled property sweeps in the style of
//! `properties.rs`: failures print the (case, d, n, b) tuple for
//! replay.

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::linalg::Mat;
use simplex_gp::mvm::{DenseMvm, ExactMvm, MvmOperator, Shifted, SimplexMvm};
use simplex_gp::solvers::{cg, cg_block, lanczos, lanczos_block, CgOptions};
use simplex_gp::util::Pcg64;

const BATCHES: [usize; 3] = [1, 3, 8];

fn case_rng(seed: u64) -> Pcg64 {
    Pcg64::with_stream(0x5eed_cafe, seed)
}

/// |a - b| must be ≤ 1e-12 absolutely and relative to the magnitude —
/// far inside the 1e-10 acceptance bound, since the block engine runs
/// the same FP operations per RHS.
fn assert_matches(a: f64, b: f64, ctx: &str) {
    let tol = 1e-12 * (1.0 + a.abs().max(b.abs()));
    assert!((a - b).abs() <= tol, "{ctx}: {a} vs {b}");
}

#[test]
fn simplex_block_mvm_matches_single_across_shapes() {
    // The tentpole property: random d ∈ {2..8}, B ∈ {1, 3, 8} — the
    // one-pass batched splat→blur→slice equals per-vector filtering.
    for case in 0..12u64 {
        let mut rng = case_rng(case);
        let d = 2 + rng.below(7); // 2..=8
        let n = 50 + rng.below(150);
        let ell = rng.uniform_in(0.4, 2.0);
        let order = 1 + rng.below(2); // r ∈ {1, 2}
        let x = rng.normal_vec(n * d);
        let mut k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, ell);
        k.outputscale = rng.uniform_in(0.5, 3.0);
        for symmetrize in [false, true] {
            let op = SimplexMvm::build(&x, d, &k, order).with_symmetrize(symmetrize);
            for &b in &BATCHES {
                let v = rng.normal_vec(n * b);
                let block = op.mvm_block(&v, b);
                for c in 0..b {
                    let single = op.mvm(&v[c * n..(c + 1) * n]);
                    for i in 0..n {
                        assert_matches(
                            block[c * n + i],
                            single[i],
                            &format!(
                                "case {case} (d={d} n={n} b={b} sym={symmetrize}) rhs {c} row {i}"
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lattice_block_filter_matches_filter_across_shapes() {
    // Same property one layer down, on the raw lattice (unit scale),
    // including the b = 1 degenerate case being *exactly* the single
    // path.
    for case in 0..8u64 {
        let mut rng = case_rng(100 + case);
        let d = 2 + rng.below(7);
        let n = 40 + rng.below(120);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Matern32, d, 1.0);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        for &b in &BATCHES {
            let v = rng.normal_vec(n * b);
            let block = lat.filter_block(&v, b);
            for c in 0..b {
                let single = lat.mvm(&v[c * n..(c + 1) * n]);
                for i in 0..n {
                    assert_matches(
                        block[c * n + i],
                        single[i],
                        &format!("case {case} (d={d} n={n} b={b}) rhs {c} row {i}"),
                    );
                }
            }
        }
    }
}

#[test]
fn exact_and_shifted_block_mvm_match_single() {
    for case in 0..6u64 {
        let mut rng = case_rng(200 + case);
        let d = 2 + rng.below(7);
        let n = 40 + rng.below(80);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Matern52, d, 1.2);
        let exact = ExactMvm::new(&k, &x, d);
        let shift = rng.uniform_in(0.01, 1.0);
        let shifted = Shifted::new(&exact, shift);
        for &b in &BATCHES {
            let v = rng.normal_vec(n * b);
            let eb = exact.mvm_block(&v, b);
            let sb = shifted.mvm_block(&v, b);
            for c in 0..b {
                let row = &v[c * n..(c + 1) * n];
                let single = exact.mvm(row);
                for i in 0..n {
                    let ctx = format!("case {case} (d={d} n={n} b={b}) rhs {c} row {i}");
                    assert_matches(eb[c * n + i], single[i], &ctx);
                    assert_matches(sb[c * n + i], single[i] + shift * row[i], &ctx);
                }
            }
        }
    }
}

fn spd_op(n: usize, rng: &mut Pcg64) -> DenseMvm {
    let mut b = Mat::zeros(n, n);
    for i in 0..n * n {
        b.data[i] = rng.normal();
    }
    let mut a = b.matmul(&b.transpose());
    a.add_diag(n as f64 * rng.uniform_in(0.3, 2.0));
    DenseMvm { mat: a }
}

#[test]
fn block_cg_iteration_counts_match_sequential_cg() {
    // Acceptance property: block-CG converges each RHS in exactly the
    // iterations its sequential solve takes (per-column arithmetic is
    // the same FP sequence), and the shared loop runs max over RHS.
    for case in 0..8u64 {
        let mut rng = case_rng(300 + case);
        let n = 30 + rng.below(60);
        let op = spd_op(n, &mut rng);
        for &b in &BATCHES {
            let rhs = rng.normal_vec(n * b);
            let opts = CgOptions {
                tol: 1e-9,
                max_iters: 500,
                min_iters: 1,
            };
            let res = cg_block(&op, &rhs, b, opts);
            let mut slowest = 0usize;
            for c in 0..b {
                let single = cg(&op, &rhs[c * n..(c + 1) * n], opts);
                assert_eq!(
                    res.rhs_iterations[c], single.iterations,
                    "case {case} (n={n} b={b}) rhs {c}: {} vs {} iterations",
                    res.rhs_iterations[c], single.iterations
                );
                for i in 0..n {
                    assert!(
                        (res.x[c * n + i] - single.x[i]).abs() < 1e-10,
                        "case {case} rhs {c} row {i}"
                    );
                }
                slowest = slowest.max(single.iterations);
            }
            assert_eq!(res.iterations, slowest, "case {case} b={b}");
        }
    }
}

#[test]
fn block_cg_on_lattice_operator_matches_sequential() {
    // The production solve: (symmetrized lattice + σ²I) block-solved
    // for target + probes together must equal the sequential solves.
    for case in 0..4u64 {
        let mut rng = case_rng(400 + case);
        let d = 2 + rng.below(5);
        let n = 80 + rng.below(120);
        let noise = rng.uniform_in(0.05, 0.5);
        let x = rng.normal_vec(n * d);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
        let op = SimplexMvm::build(&x, d, &k, 1).with_symmetrize(true);
        let shifted = Shifted::new(&op, noise);
        let b = 4;
        let rhs = rng.normal_vec(n * b);
        let opts = CgOptions {
            tol: 1e-8,
            max_iters: 500,
            min_iters: 1,
        };
        let res = cg_block(&shifted, &rhs, b, opts);
        for c in 0..b {
            let single = cg(&shifted, &rhs[c * n..(c + 1) * n], opts);
            assert_eq!(
                res.rhs_iterations[c], single.iterations,
                "case {case} (d={d} n={n}) rhs {c} iterations"
            );
            for i in 0..n {
                assert!(
                    (res.x[c * n + i] - single.x[i]).abs()
                        < 1e-10 * (1.0 + single.x[i].abs()),
                    "case {case} rhs {c} row {i}"
                );
            }
        }
    }
}

#[test]
fn block_lanczos_tridiagonals_match_sequential() {
    for case in 0..4u64 {
        let mut rng = case_rng(500 + case);
        let n = 40 + rng.below(40);
        let op = spd_op(n, &mut rng);
        let p = 1 + rng.below(4);
        let q0 = rng.normal_vec(n * p);
        let t = 15 + rng.below(15);
        let runs = lanczos_block(&op, &q0, p, t, false);
        for (c, blk) in runs.iter().enumerate() {
            let single = lanczos(&op, &q0[c * n..(c + 1) * n], t, false);
            assert_eq!(blk.alpha.len(), single.alpha.len(), "case {case} probe {c}");
            for (a, b) in blk.alpha.iter().zip(&single.alpha) {
                assert_matches(*a, *b, &format!("case {case} probe {c} alpha"));
            }
            for (a, b) in blk.beta.iter().zip(&single.beta) {
                assert_matches(*a, *b, &format!("case {case} probe {c} beta"));
            }
        }
    }
}

//! Backend-generic conformance suite (PR 10): ONE harness asserting the
//! operator invariants every interpolation backend must satisfy —
//! exercised over `&dyn MvmOperator`, so it knows nothing about
//! lattices or grids — plus the backend-specific pins:
//!
//! - **Operator conformance** (both backends): MVM symmetry, PSD-ness
//!   via Lanczos Ritz values, batch/single equivalence ≤ 1e-12,
//!   `Shifted` wrapper consistency, and build determinism (two
//!   identical builds produce bitwise-identical MVMs). These are the
//!   `invariants.rs` properties lifted out of their lattice-specific
//!   sweep into a harness any future backend plugs into.
//! - **Grid refinement** (grid only): on a smooth RBF problem the
//!   grid's MVM error against the exact O(n²d) operator decays as the
//!   per-axis resolution grows — the SKI approximation argument.
//! - **Default-path identity** (lattice): with `backend = lattice` —
//!   by default, by explicit `ServeConfig`, or by per-request label —
//!   fit, predict and coordinator replies are byte-identical to the
//!   pre-backend engine (a directly-fit `SimplexGp` twin).
//! - **Grid serving**: `"backend": "grid"` requests are served from
//!   the grid twin (tagged replies, `grid_served` counter) and match a
//!   direct `GridGp` fit of the same training set bitwise, while
//!   interleaved lattice traffic keeps its bytes.

use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{Backend, GpConfig, SimplexGp};
use simplex_gp::grid::{fit_backend, AnyGp, GridGp, GridMvm};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::linalg::eigh_tridiag;
use simplex_gp::mvm::{ExactMvm, MvmOperator, ShardedMvm, Shifted};
use simplex_gp::solvers::lanczos;
use simplex_gp::util::stats::dot;
use simplex_gp::util::Pcg64;

fn random_points(n: usize, d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::with_stream(0xc09f_0001, seed);
    rng.normal_vec(n * d)
}

/// The backend-generic operator contract. `build` must produce the
/// same operator on every call (the determinism leg builds twice);
/// everything else runs through `&dyn MvmOperator`, so any backend —
/// lattice, grid, or a future one — is checked by the same code.
fn assert_operator_conformance(build: &dyn Fn() -> Box<dyn MvmOperator>, seed: u64, tag: &str) {
    let op = build();
    let n = MvmOperator::len(op.as_ref());
    let mut rng = Pcg64::with_stream(0xc09f_0002, seed);

    // Symmetry: ⟨u, Kv⟩ = ⟨Ku, v⟩.
    let u = rng.normal_vec(n);
    let v = rng.normal_vec(n);
    let a = dot(&u, &op.mvm(&v));
    let b = dot(&v, &op.mvm(&u));
    assert!(
        (a - b).abs() <= 1e-10 * (1.0 + a.abs().max(b.abs())),
        "{tag}: asymmetry {a} vs {b}"
    );

    // PSD-ness: Lanczos Ritz values stay ≥ −1e-8 relative to the top —
    // the Krylov solvers' working assumption about every backend.
    let q0 = rng.normal_vec(n);
    let lr = lanczos(op.as_ref(), &q0, 30, false);
    let (ritz, _) = eigh_tridiag(&lr.alpha, &lr.beta);
    let top = ritz.last().copied().unwrap_or(0.0).max(1.0);
    let bottom = ritz.first().copied().unwrap_or(0.0);
    assert!(
        bottom >= -1e-8 * top,
        "{tag}: min Ritz {bottom:.3e} (top {top:.3e})"
    );

    // Batch/single equivalence: mvm_block row c equals mvm on RHS c.
    for bsz in [1usize, 7] {
        let vb = rng.normal_vec(n * bsz);
        let block = op.mvm_block(&vb, bsz);
        for col in 0..bsz {
            let single = op.mvm(&vb[col * n..(col + 1) * n]);
            for i in 0..n {
                let (got, want) = (block[col * n + i], single[i]);
                assert!(
                    (got - want).abs() <= 1e-12,
                    "{tag}: B={bsz} rhs {col} row {i}: {got} vs {want}"
                );
            }
        }
    }

    // Shifted wrapper: (K + σ²I)v row i is exactly Kv[i] + σ²·v[i].
    let shifted = Shifted::new(op.as_ref(), 0.7);
    let plain = op.mvm(&v);
    let shifted_out = shifted.mvm(&v);
    for i in 0..n {
        assert_eq!(
            shifted_out[i].to_bits(),
            (plain[i] + 0.7 * v[i]).to_bits(),
            "{tag}: Shifted row {i}"
        );
    }

    // Determinism: a second identical build yields bitwise-equal MVMs.
    let op2 = build();
    let (u1, u2) = (op.mvm(&v), op2.mvm(&v));
    for i in 0..n {
        assert_eq!(
            u1[i].to_bits(),
            u2[i].to_bits(),
            "{tag}: rebuild drifted at row {i}"
        );
    }
}

#[test]
fn lattice_backend_operator_conformance() {
    for &d in &[2usize, 3] {
        for &p in &[1usize, 3] {
            for &family in &[KernelFamily::Rbf, KernelFamily::Matern32] {
                let n = 150;
                let seed = 0xc0_0000 + (d * 100 + p * 10) as u64;
                let x = random_points(n, d, seed);
                let k = ArdKernel::with_lengthscale(family, d, 1.0);
                let build = || -> Box<dyn MvmOperator> {
                    Box::new(ShardedMvm::build(&x, d, &k, 1, p).with_symmetrize(true))
                };
                let tag = format!("lattice d={d} P={p} {family:?}");
                assert_operator_conformance(&build, seed, &tag);
            }
        }
    }
}

#[test]
fn grid_backend_operator_conformance() {
    for &d in &[2usize, 3] {
        for &family in &[KernelFamily::Rbf, KernelFamily::Matern32] {
            let n = 150;
            let seed = 0xc1_0000 + d as u64;
            let x = random_points(n, d, seed);
            let k = ArdKernel::with_lengthscale(family, d, 1.0);
            let build = || -> Box<dyn MvmOperator> {
                Box::new(GridMvm::build(&x, d, &k, 16).unwrap())
            };
            let tag = format!("grid d={d} {family:?}");
            assert_operator_conformance(&build, seed, &tag);
        }
    }
}

#[test]
fn grid_interpolation_error_decays_with_resolution() {
    // The SKI pin: on a smooth RBF kernel the grid MVM converges to the
    // exact O(n²d) MVM as the per-axis resolution grows.
    let (n, d) = (220usize, 2usize);
    let x = random_points(n, d, 0xc2_0001);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let exact_op = ExactMvm::new(&kernel, &x, d);
    let v = Pcg64::with_stream(0xc2_0002, 1).normal_vec(n);
    let exact = MvmOperator::mvm(&exact_op, &v);
    let norm = dot(&exact, &exact).sqrt().max(1e-12);
    let mut errs = Vec::new();
    for &points in &[12usize, 24, 48] {
        let grid = GridMvm::build(&x, d, &kernel, points).unwrap();
        let approx = MvmOperator::mvm(&grid, &v);
        let err = exact
            .iter()
            .zip(&approx)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / norm;
        errs.push(err);
    }
    assert!(
        errs[2] < 0.5 * errs[0],
        "refinement did not reduce error: {errs:?}"
    );
    assert!(
        errs[2] < 0.05,
        "finest grid still {:.3e} relative error",
        errs[2]
    );
}

/// Deterministic 2-D regression problem shared by the serving legs.
fn problem(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, usize) {
    let d = 2;
    let mut rng = Pcg64::with_stream(0xc3_0000, seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d] + 0.5 * x[i * d + 1]).sin() + 0.05 * rng.normal())
        .collect();
    (x, y, d)
}

#[test]
fn fit_backend_lattice_is_the_pre_backend_engine_bitwise() {
    // `fit_backend(Lattice, ..)` — the default dispatch path — must be
    // `SimplexGp::fit` bit for bit: same α, same predictions.
    let (x, y, d) = problem(180, 1);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.6);
    let cfg = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    let direct = SimplexGp::fit(&x, &y, d, kernel.clone(), 0.05, cfg.clone()).unwrap();
    let via = fit_backend(Backend::Lattice, &x, &y, d, kernel, 0.05, cfg).unwrap();
    assert_eq!(via.backend(), Backend::Lattice);
    let xq = random_points(7, d, 0xc3_1000);
    let (md, vd) = direct.predict(&xq);
    let (mv, vv) = via.predict(&xq);
    for i in 0..md.len() {
        assert_eq!(md[i].to_bits(), mv[i].to_bits(), "mean row {i}");
        assert_eq!(vd[i].to_bits(), vv[i].to_bits(), "var row {i}");
    }
    match via {
        AnyGp::Lattice(gp) => assert_eq!(gp.alpha(), direct.alpha(), "α diverged"),
        AnyGp::Grid(_) => panic!("lattice dispatch produced a grid model"),
    }
}

fn fit_serving_model(x: &[f64], y: &[f64], d: usize) -> SimplexGp {
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.6);
    let cfg = GpConfig {
        shards: 2,
        ..GpConfig::default()
    };
    SimplexGp::fit(x, y, d, kernel, 0.05, cfg).unwrap()
}

#[test]
fn lattice_serving_replies_are_byte_identical_across_backend_surfaces() {
    // The refactor acceptance pin: a default server (no backend set),
    // an explicit `backend: Lattice` server, and per-request
    // `"backend": "lattice"` labels all produce replies byte-identical
    // to the direct twin — the dispatch layer costs the default path
    // nothing, not even an FP rounding.
    let (x, y, d) = problem(200, 2);
    let twin = fit_serving_model(&x, &y, d);
    let default_server = Server::start(
        fit_serving_model(&x, &y, d),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let explicit_server = Server::start(
        fit_serving_model(&x, &y, d),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            backend: Backend::Lattice,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut c_default = Client::connect(&default_server.local_addr).unwrap();
    let mut c_explicit = Client::connect(&explicit_server.local_addr).unwrap();

    let xq = random_points(9, d, 0xc3_2000);
    let want_mean = twin.predict_mean(&xq);
    let unlabeled = c_default.predict(&xq, d).unwrap();
    let labeled = c_default.predict_backend(&xq, d, "lattice").unwrap().0;
    let explicit = c_explicit.predict(&xq, d).unwrap();
    for i in 0..want_mean.len() {
        let w = want_mean[i].to_bits();
        assert_eq!(unlabeled[i].to_bits(), w, "unlabeled mean row {i}");
        assert_eq!(labeled[i].to_bits(), w, "labeled mean row {i}");
        assert_eq!(explicit[i].to_bits(), w, "explicit-server mean row {i}");
    }
    // A lattice reply carries no backend tag — the wire bytes are the
    // pre-backend protocol.
    let (_, reply) = c_default.predict_backend(&xq, d, "lattice").unwrap();
    assert!(reply.get("backend").is_none(), "lattice reply grew a tag");

    // mvm surface: unit-outputscale lattice MVM, bit for bit.
    let v = Pcg64::with_stream(0xc3_2001, 3).normal_vec(twin.n_train());
    let want_u = twin.operator().lattice.mvm(&v);
    let u_unlabeled = c_default.mvm(&v).unwrap();
    let u_labeled = c_default.mvm_backend(&v, "lattice").unwrap();
    for i in 0..want_u.len() {
        assert_eq!(u_unlabeled[i].to_bits(), want_u[i].to_bits(), "mvm row {i}");
        assert_eq!(u_labeled[i].to_bits(), want_u[i].to_bits(), "labeled mvm row {i}");
    }

    // Unknown labels are rejected at parse time with a usable message.
    let err = c_default.predict_backend(&xq, d, "tesseract").unwrap_err();
    assert!(
        err.to_string().contains("unknown backend"),
        "unexpected error: {err}"
    );

    let st = c_default.stats().unwrap();
    assert_eq!(
        st.get("grid_served").and_then(|v| v.as_f64()),
        Some(0.0),
        "lattice-only traffic touched the grid twin"
    );
    assert_eq!(
        st.get("backend").and_then(|v| v.as_str()),
        Some("lattice"),
        "stats backend tag"
    );
    default_server.shutdown();
    explicit_server.shutdown();
}

#[test]
fn grid_requests_served_from_grid_twin_and_lattice_bytes_survive() {
    // Per-request routing: `"backend": "grid"` predict/mvm replies must
    // match a direct GridGp fit of the same training set bitwise, be
    // tagged, and count in `grid_served` — while interleaved lattice
    // requests keep their exact pre-backend bytes.
    let (x, y, d) = problem(200, 4);
    let lattice_twin = fit_serving_model(&x, &y, d);
    let grid_twin = GridGp::fit(
        &x,
        &y,
        d,
        lattice_twin.kernel.clone(),
        lattice_twin.noise,
        lattice_twin.config.clone(),
    )
    .unwrap();
    let server = Server::start(
        fit_serving_model(&x, &y, d),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();

    let xq = random_points(6, d, 0xc3_3000);
    let want_lat = lattice_twin.predict_mean(&xq);
    let want_grid = grid_twin.predict_mean(&xq);
    for round in 0..3 {
        let lat = client.predict(&xq, d).unwrap();
        let (grid, reply) = client.predict_backend(&xq, d, "grid").unwrap();
        assert_eq!(
            reply.get("backend").and_then(|v| v.as_str()),
            Some("grid"),
            "round {round}: grid reply untagged"
        );
        for i in 0..want_lat.len() {
            assert_eq!(
                lat[i].to_bits(),
                want_lat[i].to_bits(),
                "round {round} lattice mean row {i}"
            );
            assert_eq!(
                grid[i].to_bits(),
                want_grid[i].to_bits(),
                "round {round} grid mean row {i}"
            );
        }
    }
    // Grid mvm: unit-outputscale, matching the direct grid operator.
    let v = Pcg64::with_stream(0xc3_3001, 5).normal_vec(grid_twin.n_train());
    let want_u = grid_twin.operator().mvm_unit(&v);
    let got_u = client.mvm_backend(&v, "grid").unwrap();
    for i in 0..want_u.len() {
        assert_eq!(got_u[i].to_bits(), want_u[i].to_bits(), "grid mvm row {i}");
    }

    let st = client.stats().unwrap();
    let grid_served = st.get("grid_served").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(grid_served, 4.0, "3 grid predicts + 1 grid mvm");
    server.shutdown();
}

#[test]
fn grid_default_server_routes_unlabeled_requests_to_the_grid() {
    // A `backend: Grid` server serves unlabeled predicts from the grid
    // twin; per-request "lattice" labels still reach the lattice.
    let (x, y, d) = problem(160, 5);
    let lattice_twin = fit_serving_model(&x, &y, d);
    let grid_twin = GridGp::fit(
        &x,
        &y,
        d,
        lattice_twin.kernel.clone(),
        lattice_twin.noise,
        lattice_twin.config.clone(),
    )
    .unwrap();
    let server = Server::start(
        fit_serving_model(&x, &y, d),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            backend: Backend::Grid,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();
    let xq = random_points(5, d, 0xc3_4000);
    let unlabeled = client.predict(&xq, d).unwrap();
    let want_grid = grid_twin.predict_mean(&xq);
    for i in 0..want_grid.len() {
        assert_eq!(
            unlabeled[i].to_bits(),
            want_grid[i].to_bits(),
            "grid-default mean row {i}"
        );
    }
    let labeled = client.predict_backend(&xq, d, "lattice").unwrap().0;
    let want_lat = lattice_twin.predict_mean(&xq);
    for i in 0..want_lat.len() {
        assert_eq!(
            labeled[i].to_bits(),
            want_lat[i].to_bits(),
            "lattice-labeled mean row {i}"
        );
    }
    let st = client.stats().unwrap();
    assert_eq!(
        st.get("backend").and_then(|v| v.as_str()),
        Some("grid"),
        "stats backend tag"
    );
    server.shutdown();
}

//! Fig. 6 — MVM wall time: Simplex-GP (order r = 1) vs the exact MVM
//! (KeOps analog: multithreaded tile-recomputed O(n²d)), per dataset,
//! as n grows. The paper reports ~10× speedups at n ≳ 1e5.

use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{ExactMvm, MvmOperator, SimplexMvm};
use simplex_gp::util::bench::{fmt_secs, time_budget, Table};
use simplex_gp::util::Pcg64;

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let sizes: Vec<usize> = if quick {
        vec![1000, 4000]
    } else {
        vec![2000, 8000, 32000, 64000]
    };
    let budget = if quick { 0.3 } else { 2.0 };
    let mut table = Table::new(&["dataset", "n_train", "exact_mvm", "simplex_mvm", "speedup"]);
    for spec in PAPER_DATASETS {
        for &n in &sizes {
            if n > spec.n_default {
                continue;
            }
            let ds = generate(spec.name, n, 0);
            let sp = split_standardize(&ds, 1);
            let x = &sp.train.x;
            let nn = sp.train.n();
            let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, spec.d, 1.0);
            let mut rng = Pcg64::new(5);
            let v = rng.normal_vec(nn);
            let simplex = SimplexMvm::build(x, spec.d, &kernel, 1);
            let ts = time_budget("simplex", budget, 30, || simplex.mvm(&v));
            // Exact gets expensive fast; cap its budget.
            let exact = ExactMvm::new(&kernel, x, spec.d);
            let te = time_budget("exact", budget, 10, || exact.mvm(&v));
            table.row(&[
                spec.name.to_string(),
                nn.to_string(),
                fmt_secs(te.median_s),
                fmt_secs(ts.median_s),
                format!("{:.1}x", te.median_s / ts.median_s.max(1e-12)),
            ]);
        }
    }
    println!("\nFig. 6 — MVM wall time, Simplex-GP (r=1) vs exact (KeOps analog)\n");
    table.print();
    table.write_csv("fig6_mvm_speed");
    println!("\nShape check (paper): the speedup grows with n (exact is O(n^2 d), the\nlattice O(n d^2)); crossover sits at moderate n and reaches order-10x by n ~ 1e5.\n");
}

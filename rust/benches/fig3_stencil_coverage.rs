//! Fig. 3 — the spatial-vs-Fourier coverage trade-off behind the §4.1
//! spacing search: emits both coverage curves as functions of s for each
//! kernel family, plus the intersection (the chosen spacing).

use simplex_gp::kernels::KernelFamily;
use simplex_gp::stencil::{fourier_coverage, optimal_spacing, spatial_coverage};
use simplex_gp::util::bench::Table;

fn main() {
    let families = [
        KernelFamily::Rbf,
        KernelFamily::Matern12,
        KernelFamily::Matern32,
        KernelFamily::Matern52,
    ];
    let r = 1usize;
    let mut table = Table::new(&["family", "s", "spatial_coverage", "fourier_coverage"]);
    for fam in families {
        for k in 1..=40 {
            let s = 0.1 * k as f64;
            table.row(&[
                fam.name().to_string(),
                format!("{s:.2}"),
                format!("{:.4}", spatial_coverage(fam, r, s)),
                format!("{:.4}", fourier_coverage(fam, s)),
            ]);
        }
    }
    println!("\nFig. 3 — coverage curves (order r = {r})\n");
    table.write_csv("fig3_stencil_coverage");

    let mut summary = Table::new(&["family", "optimal_s", "spatial==fourier", "side_tap"]);
    for fam in families {
        let s = optimal_spacing(fam, r);
        let cov = spatial_coverage(fam, r, s);
        let side = fam.profile(s * s);
        summary.row(&[
            fam.name().to_string(),
            format!("{s:.4}"),
            format!("{cov:.4}"),
            format!("{side:.4}"),
        ]);
    }
    println!("Balanced-coverage spacings (Eq. 9 intersections):\n");
    summary.print();
    summary.write_csv("fig3_optimal_spacing");
    println!("\nShape check: spatial coverage increases and Fourier coverage decreases in s;\nthe RBF r=1 side tap lands near 0.5 (the classical [.5, 1, .5] stencil).\n");
}

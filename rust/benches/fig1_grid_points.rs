//! Fig. 1 — grid points required: SKI's dense rectangular grid needs at
//! least 2^d points (and in practice g^d), while the permutohedral
//! lattice opens at most n·(d+1) and in practice far fewer. Prints the
//! counts per dimension on a fixed point cloud.

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::util::bench::Table;
use simplex_gp::util::Pcg64;

fn main() {
    let n = if simplex_gp::util::bench::quick_mode() { 500 } else { 2000 };
    let grid_per_dim = 10usize; // modest SKI resolution
    let mut table = Table::new(&[
        "d",
        "ski_grid_points_g10",
        "ski_min_2^d",
        "simplex_m",
        "simplex_bound_n(d+1)",
    ]);
    let mut rng = Pcg64::new(1);
    for d in [1usize, 2, 3, 4, 6, 8, 10, 12, 16, 20] {
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
        let lat = PermutohedralLattice::build(&x, d, &k, 1);
        let ski: f64 = (grid_per_dim as f64).powi(d as i32);
        let ski_min: f64 = 2f64.powi(d as i32);
        table.row(&[
            d.to_string(),
            format!("{ski:.3e}"),
            format!("{ski_min:.0}"),
            lat.m.to_string(),
            (n * (d + 1)).to_string(),
        ]);
    }
    println!("\nFig. 1 — inducing/grid point counts, n = {n} standard-normal inputs\n");
    table.print();
    table.write_csv("fig1_grid_points");
    println!(
        "\nShape check (paper): SKI grows exponentially in d; the lattice stays <= n(d+1).\n"
    );
}

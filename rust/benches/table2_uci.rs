//! Table 2 — standardized test RMSE and NLL on the five benchmark
//! analogs for Exact GP, SGPR (m = 512), SKIP (rank 100) and
//! Simplex-GP, averaged over 3 seeds with 2-σ bands (paper protocol:
//! 4/9–2/9–3/9 split, standardized, Adam lr 0.1, early stopping).
//!
//! Substitution note: synthetic analogs ⇒ absolute values differ from
//! the paper; the claims under test are the *orderings* (Simplex-GP
//! beats SKIP, approaches Exact, is competitive with SGPR).
//!
//! PR 10 adds the backend head-to-head: on the low-d (d ≤ 3) datasets,
//! the permutohedral lattice vs the rectangular-grid SKI backend at a
//! matched Adam budget, one JSON row per (dataset, backend) when
//! `SIMPLEX_GP_BENCH_JSON` is set: `{"bench":"table2_uci", "dataset",
//! "backend", "d", "n", "rmse", "nll", "fit_s"}`. Pass `--backend-only`
//! to skip the (slow) baseline tables and run just the head-to-head —
//! the bench-smoke CI path.

use std::time::Instant;

use simplex_gp::baselines::{ExactGp, Sgpr, SgprConfig, SkipGp};
use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::gp::{train, TrainConfig};
use simplex_gp::grid::train_grid;
use simplex_gp::kernels::KernelFamily;
use simplex_gp::util::bench::{append_bench_json, Table};
use simplex_gp::util::json::Json;
use simplex_gp::util::stats::{gaussian_nll, mean, rmse, std};

fn two_sigma(vals: &[f64]) -> String {
    format!("{:.3}±{:.3}", mean(vals), 2.0 * std(vals))
}

fn emit_backend_row(dataset: &str, backend: &str, d: usize, n: usize, r: f64, l: f64, s: f64) {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("table2_uci".to_string()));
    obj.insert("dataset".to_string(), Json::Str(dataset.to_string()));
    obj.insert("backend".to_string(), Json::Str(backend.to_string()));
    for (k, v) in [
        ("d", d as f64),
        ("n", n as f64),
        ("rmse", r),
        ("nll", l),
        ("fit_s", s),
    ] {
        obj.insert(k.to_string(), Json::Num(v));
    }
    append_bench_json(&Json::Obj(obj));
}

/// Lattice vs grid at a matched training budget on the low-d datasets.
/// Both learn outputscale + noise by Adam on the MLL; the lattice also
/// learns lengthscales (the grid trainer holds them at init — part of
/// the trade the table quantifies, not an unfair budget).
fn backend_head_to_head(quick: bool) {
    let n_cap = if quick { 1200 } else { 4000 };
    let nll_points = 128;
    let mut table = Table::new(&["dataset", "backend", "rmse", "nll", "fit_s"]);
    for spec in PAPER_DATASETS {
        if spec.d > 3 {
            continue; // 2^d interp corners: the grid targets low-d
        }
        let n = n_cap.min(spec.n_default);
        let ds = generate(spec.name, n, 0);
        let sp = split_standardize(&ds, 10);
        let d = spec.d;
        let (xtr, ytr) = (&sp.train.x, &sp.train.y);
        let (xv, yv) = (&sp.val.x, &sp.val.y);
        let (xte, yte) = (&sp.test.x, &sp.test.y);
        let t_nll = nll_points.min(yte.len());
        let cfg = TrainConfig {
            epochs: if quick { 6 } else { 20 },
            probes: 6,
            seed: 0,
            ..TrainConfig::default()
        };

        let t0 = Instant::now();
        let lat = train(xtr, ytr, xv, yv, d, KernelFamily::Matern32, cfg.clone()).unwrap();
        let lat_s = t0.elapsed().as_secs_f64();
        let lat_rmse = rmse(&lat.model.predict_mean(xte), yte);
        let (ms, vs) = lat.model.predict(&xte[..t_nll * d]);
        let lat_nll = gaussian_nll(&ms, &vs, &yte[..t_nll]);
        table.row(&[
            spec.name.to_string(),
            "lattice".to_string(),
            format!("{lat_rmse:.3}"),
            format!("{lat_nll:.3}"),
            format!("{lat_s:.2}"),
        ]);
        emit_backend_row(spec.name, "lattice", d, n, lat_rmse, lat_nll, lat_s);

        let t0 = Instant::now();
        let grid = train_grid(xtr, ytr, xv, yv, d, KernelFamily::Matern32, &cfg).unwrap();
        let grid_s = t0.elapsed().as_secs_f64();
        let grid_rmse = rmse(&grid.model.predict_mean(xte), yte);
        let (ms, vs) = grid.model.predict(&xte[..t_nll * d]);
        let grid_nll = gaussian_nll(&ms, &vs, &yte[..t_nll]);
        table.row(&[
            spec.name.to_string(),
            "grid".to_string(),
            format!("{grid_rmse:.3}"),
            format!("{grid_nll:.3}"),
            format!("{grid_s:.2}"),
        ]);
        emit_backend_row(spec.name, "grid", d, n, grid_rmse, grid_nll, grid_s);
        println!("[table2] backend head-to-head finished {}", spec.name);
    }
    println!("\nTable 2c — lattice vs rectangular-grid SKI backend (matched Adam budget)\n");
    table.print();
    table.write_csv("table2_backends");
}

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let backend_only = std::env::args().any(|a| a == "--backend-only");
    if backend_only {
        backend_head_to_head(quick);
        return;
    }
    let trials: u64 = if quick { 1 } else { 3 };
    let n_cap = if quick { 1500 } else { 4000 };
    let exact_cap = 2000; // exact-GP O(n²d) solves dominate beyond this
    let skip_rank = 30; // within the paper's 20–100 band; rank 100 joint
                        // rebuilds are prohibitive on this 1-core testbed
    let nll_points = 128;

    let mut rmse_table = Table::new(&["dataset", "exact_gp", "sgpr", "skip", "simplex_gp"]);
    let mut nll_table = Table::new(&["dataset", "exact_gp", "sgpr", "skip", "simplex_gp"]);

    for spec in PAPER_DATASETS {
        let mut r = [vec![], vec![], vec![], vec![]];
        let mut l = [vec![], vec![], vec![], vec![]];
        for trial in 0..trials {
            let n = n_cap.min(spec.n_default);
            let ds = generate(spec.name, n, trial);
            let sp = split_standardize(&ds, trial + 10);
            let d = spec.d;
            let (xtr, ytr) = (&sp.train.x, &sp.train.y);
            let (xv, yv) = (&sp.val.x, &sp.val.y);
            let (xte, yte) = (&sp.test.x, &sp.test.y);
            let t_nll = nll_points.min(yte.len());

            // --- Simplex-GP: full MLL training ---
            let cfg = TrainConfig {
                epochs: if quick { 8 } else { 20 },
                probes: 6,
                seed: trial,
                ..TrainConfig::default()
            };
            let out = train(xtr, ytr, xv, yv, d, KernelFamily::Matern32, cfg).unwrap();
            let model = out.model;
            let pred = model.predict_mean(xte);
            r[3].push(rmse(&pred, yte));
            let (ms, vs) = model.predict(&xte[..t_nll * d]);
            l[3].push(gaussian_nll(&ms, &vs, &yte[..t_nll]));
            // Transfer the learned hyperparameters to the baselines
            // (paper trains each with the same Adam protocol; the learned
            // kernels agree qualitatively per its Appendix C, so a shared
            // kernel isolates the approximation quality comparison).
            let kernel = model.kernel.clone();
            let noise = model.noise;

            // --- Exact GP (subsampled if needed) ---
            let ne = exact_cap.min(ytr.len());
            let gp = ExactGp::fit(&xtr[..ne * d], &ytr[..ne], d, kernel.clone(), noise, 1e-2)
                .unwrap();
            let pred = gp.predict_mean(xte);
            r[0].push(rmse(&pred, yte));
            let (ms, vs) = gp.predict(&xte[..t_nll * d]);
            l[0].push(gaussian_nll(&ms, &vs, &yte[..t_nll]));

            // --- SGPR m=512 ---
            let scfg = SgprConfig {
                m_inducing: 512.min(ytr.len() / 2),
                epochs: if quick { 10 } else { 25 },
                seed: trial,
                ..SgprConfig::default()
            };
            let sg = Sgpr::train(xtr, ytr, d, KernelFamily::Matern32, scfg).unwrap();
            let (ms_all, _) = sg.predict(xte);
            r[1].push(rmse(&ms_all, yte));
            let (ms, vs) = sg.predict(&xte[..t_nll * d]);
            l[1].push(gaussian_nll(&ms, &vs, &yte[..t_nll]));

            // --- SKIP ---
            let sk = SkipGp::fit(xtr, ytr, d, kernel.clone(), noise, skip_rank, trial, 1e-2)
                .unwrap();
            match sk.predict_mean(xte) {
                Ok(pred) => {
                    r[2].push(rmse(&pred, yte));
                    let (ms, vs) = sk.predict(&xte[..t_nll * d]).unwrap();
                    l[2].push(gaussian_nll(&ms, &vs, &yte[..t_nll]));
                }
                Err(e) => {
                    eprintln!("skip failed on {}: {e}", spec.name);
                    r[2].push(f64::NAN);
                    l[2].push(f64::NAN);
                }
            }
        }
        rmse_table.row(&[
            spec.name.to_string(),
            two_sigma(&r[0]),
            two_sigma(&r[1]),
            two_sigma(&r[2]),
            two_sigma(&r[3]),
        ]);
        nll_table.row(&[
            spec.name.to_string(),
            two_sigma(&l[0]),
            two_sigma(&l[1]),
            two_sigma(&l[2]),
            two_sigma(&l[3]),
        ]);
        // Incremental printing: these runs are long.
        println!("[table2] finished {}", spec.name);
    }

    println!("\nTable 2a — standardized test RMSE (mean ± 2σ over {trials} trials)\n");
    rmse_table.print();
    rmse_table.write_csv("table2_rmse");
    println!("\nTable 2b — test NLL ({nll_points}-point subsample for variance solves)\n");
    nll_table.print();
    nll_table.write_csv("table2_nll");
    println!("\nShape check (paper): Simplex-GP < SKIP on RMSE everywhere, close to\nExact GP, competitive with SGPR.\n");

    backend_head_to_head(quick);
}

//! Table 4 — per-epoch training runtime: plain CG at tolerance 1e-2 vs
//! 1e-4 vs RR-CG (tol 1e-8 with randomized truncation). The paper's
//! claim: tight CG is several-fold slower; RR-CG stabilizes training at
//! a runtime between the two.

use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::gp::{train, SolveMode, TrainConfig};
use simplex_gp::kernels::KernelFamily;
use simplex_gp::util::bench::{fmt_secs, Table};
use simplex_gp::util::stats::mean;

fn epoch_time(
    sp: &simplex_gp::datasets::Split,
    d: usize,
    solve: SolveMode,
    epochs: usize,
) -> (f64, f64) {
    let cfg = TrainConfig {
        epochs,
        probes: 6,
        solve,
        patience: epochs + 1, // no early stopping inside the measurement
        // Start ill-conditioned (small noise): this is the regime where
        // CG tolerance dominates runtime, as in the paper's full-size
        // runs.
        init_noise: 1e-3,
        min_noise: 1e-4,
        ..TrainConfig::default()
    };
    let out = train(
        &sp.train.x,
        &sp.train.y,
        &sp.val.x,
        &sp.val.y,
        d,
        KernelFamily::Matern32,
        cfg,
    )
    .unwrap();
    (
        mean(&out.records.iter().map(|r| r.epoch_secs).collect::<Vec<_>>()),
        mean(&out.records.iter().map(|r| r.solve_iters as f64).collect::<Vec<_>>()),
    )
}

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let n_cap = if quick { 1500 } else { 8000 };
    let epochs = if quick { 2 } else { 4 };
    let mut table = Table::new(&[
        "dataset",
        "CG(1e-2)",
        "iters",
        "CG(1e-4)",
        "iters",
        "RR-CG(1e-8)",
        "iters",
    ]);
    for spec in PAPER_DATASETS {
        let n = n_cap.min(spec.n_default);
        let ds = generate(spec.name, n, 0);
        let sp = split_standardize(&ds, 1);
        let (t_loose, i_loose) = epoch_time(&sp, spec.d, SolveMode::Cg { tol: 1e-2 }, epochs);
        let (t_tight, i_tight) = epoch_time(&sp, spec.d, SolveMode::Cg { tol: 1e-4 }, epochs);
        let (t_rr, i_rr) = epoch_time(
            &sp,
            spec.d,
            SolveMode::RrCg {
                geom_p: 0.05,
                min_iters: 10,
            },
            epochs,
        );
        table.row(&[
            spec.name.to_string(),
            fmt_secs(t_loose),
            format!("{i_loose:.0}"),
            fmt_secs(t_tight),
            format!("{i_tight:.0}"),
            fmt_secs(t_rr),
            format!("{i_rr:.0}"),
        ]);
        println!("[table4] finished {}", spec.name);
    }
    println!("\nTable 4 — mean per-epoch training time by solver\n");
    table.print();
    table.write_csv("table4_cg_runtime");
    println!("\nShape check (paper): CG(1e-4) is severalfold slower than CG(1e-2);\nRR-CG lands between them while remaining unbiased.\n");
}

//! Preconditioned block-CG sweep (the PR-3 acceptance bench): CG
//! iterations and wall time for rank ∈ {0, 25, 100} per-shard
//! pivoted-Cholesky preconditioners × shard count P ∈ {1, 4} on the
//! symmetrized lattice operator `K̃ + σ²I`.
//!
//! Conditioning regime: with the tiny paper-style noise (σ² = 1e-2 on
//! unit-outputscale standardized data) the condition number of
//! `K + σ²I` grows with the kernel's smoothness — the *larger*
//! lengthscale is the ill-conditioned setting (top eigenvalue ≈ n·s²,
//! smallest ≈ σ²), which is exactly where GPyTorch-style pivoted
//! Cholesky bites: rank k captures the dominant eigenspace and the
//! preconditioned spectrum clusters near 1. The sweep runs a rough and
//! a smooth lengthscale and asserts acceptance (≥ 1.5× iteration
//! reduction at rank 100) on whichever setting plain CG finds hardest.
//!
//! With `SIMPLEX_GP_BENCH_JSON=<path>` set (CI bench-smoke), every cell
//! is appended to the perf-trajectory file as
//! `{"bench", "n", "d", "ls", "rank", "shards", "cg_iters", "ns_per_solve"}`.
//!
//!     cargo bench --bench precond_cg [-- --quick]

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{ShardedMvm, Shifted};
use simplex_gp::solvers::{cg_block_precond, CgOptions, Precond};
use simplex_gp::util::bench::{append_bench_json, bench_record, fmt_secs, quick_mode, Table};
use simplex_gp::util::Pcg64;

fn main() {
    let quick = quick_mode();
    let d = 4;
    let n: usize = if quick { 2_048 } else { 16_384 };
    let sigma2 = 1e-2;
    let nrhs = 4;
    let opts = CgOptions {
        tol: 1e-6,
        max_iters: 500,
        min_iters: 1,
    };

    // Sort along the first coordinate so contiguous shards are spatial
    // slabs (the locality assumption of ARCHITECTURE.md §Sharding).
    let x: Vec<f64> = {
        let mut rng = Pcg64::new(31);
        let raw: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| raw[a * d].total_cmp(&raw[b * d]));
        let mut sorted = Vec::with_capacity(n * d);
        for i in order {
            sorted.extend_from_slice(&raw[i * d..(i + 1) * d]);
        }
        sorted
    };
    let rhs = {
        let mut rng = Pcg64::new(32);
        rng.normal_vec(n * nrhs)
    };

    println!(
        "preconditioned block-CG: n = {n}, d = {d}, sigma2 = {sigma2}, {} RHS, tol = {:.0e}\n",
        nrhs, opts.tol
    );
    let mut table = Table::new(&[
        "lengthscale",
        "P",
        "rank",
        "build",
        "solve",
        "CG iters",
        "iter cut",
    ]);

    // (ls, p) -> (baseline iters, rank-100 iters, max |Δx| vs baseline).
    let mut cells: Vec<(f64, usize, usize, usize, f64)> = Vec::new();
    for &ls in &[0.5f64, 2.0] {
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, ls);
        for &p in &[1usize, 4] {
            let op = ShardedMvm::build(&x, d, &kernel, 1, p).with_symmetrize(true);
            let shifted = Shifted::new(&op, sigma2);
            let mut base_iters = 0usize;
            let mut r100_iters = 0usize;
            let mut base_x: Vec<f64> = Vec::new();
            let mut max_dx = 0.0f64;
            for &rank in &[0usize, 25, 100] {
                let t0 = std::time::Instant::now();
                let pc = if rank > 0 {
                    Some(op.build_precond(&x, &kernel, rank, sigma2))
                } else {
                    None
                };
                let build_s = t0.elapsed().as_secs_f64();
                let t1 = std::time::Instant::now();
                let res = cg_block_precond(
                    &shifted,
                    &rhs,
                    nrhs,
                    opts,
                    pc.as_ref().map(|pc| pc as &dyn Precond),
                );
                let solve_s = t1.elapsed().as_secs_f64();
                if rank == 0 {
                    base_iters = res.iterations;
                    base_x = res.x.clone();
                } else {
                    let dx = res
                        .x
                        .iter()
                        .zip(&base_x)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f64, f64::max);
                    max_dx = max_dx.max(dx);
                    if rank == 100 {
                        r100_iters = res.iterations;
                    }
                }
                let cut = if rank == 0 || res.iterations == 0 {
                    "-".to_string()
                } else {
                    format!("{:.2}x", base_iters as f64 / res.iterations as f64)
                };
                table.row(&[
                    format!("{ls}"),
                    p.to_string(),
                    rank.to_string(),
                    fmt_secs(build_s),
                    fmt_secs(solve_s),
                    res.iterations.to_string(),
                    cut,
                ]);
                append_bench_json(&bench_record(
                    "precond_cg",
                    &[
                        ("n", n as f64),
                        ("d", d as f64),
                        ("ls", ls),
                        ("rank", rank as f64),
                        ("shards", p as f64),
                        ("cg_iters", res.iterations as f64),
                        ("ns_per_solve", solve_s * 1e9),
                    ],
                ));
            }
            cells.push((ls, p, base_iters, r100_iters, max_dx));
        }
    }

    println!("\nPreconditioned block-CG — iterations / latency by rank and shard count\n");
    table.print();
    table.write_csv("precond_cg");

    // Acceptance on the ill-conditioned setting: the lengthscale whose
    // P = 1 unpreconditioned solve needed the most iterations.
    let hard_ls = cells
        .iter()
        .filter(|c| c.1 == 1)
        .max_by_key(|c| c.2)
        .map(|c| c.0)
        .unwrap();
    println!(
        "\nill-conditioned setting: lengthscale = {hard_ls} (largest plain-CG iteration count)"
    );
    for &(ls, p, base, r100, max_dx) in &cells {
        if ls != hard_ls {
            continue;
        }
        let ratio = base as f64 / (r100 as f64).max(1.0);
        println!(
            "acceptance (P = {p}): rank-100 cuts CG iterations {base} -> {r100} = {ratio:.2}x {} \
             (max |dx| vs unpreconditioned {max_dx:.2e})",
            if ratio >= 1.5 {
                "(>= 1.5x: PASS)"
            } else {
                "(< 1.5x: FAIL)"
            }
        );
    }
    println!();
}

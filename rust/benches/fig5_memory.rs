//! Fig. 5 — peak memory: Simplex-GP's lattice storage vs SKIP's
//! low-rank + Lanczos working set, per dataset. The paper reports peak
//! GPU memory (SKIP OOMs on Houseelectric at 24 GB); our analog is
//! exact byte accounting of each method's data structures plus process
//! RSS, and an extrapolation of SKIP to the paper's full n.

use simplex_gp::baselines::SkipMvm;
use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::util::bench::{fmt_bytes, Table};

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let mut table = Table::new(&[
        "dataset",
        "n",
        "d",
        "simplex_bytes",
        "skip_peak_bytes",
        "ratio",
        "skip_at_full_n",
    ]);
    for spec in PAPER_DATASETS {
        let n = if quick { 2000 } else { 8000.min(spec.n_default) };
        let ds = generate(spec.name, n, 0);
        let sp = split_standardize(&ds, 1);
        let x = &sp.train.x;
        let nn = sp.train.n();
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, spec.d, 1.0);
        let lat = PermutohedralLattice::build(x, spec.d, &kernel, 1);
        let simplex_bytes = lat.storage_bytes();
        let skip = SkipMvm::build(x, spec.d, &kernel, 100, 1).unwrap();
        let skip_bytes = skip.peak_build_bytes;
        // SKIP's working set scales linearly in n (factors are n×r per
        // level); extrapolate to the paper's full dataset size.
        let skip_full = (skip_bytes as f64) * (spec.n_paper as f64 * 4.0 / 9.0) / nn as f64;
        table.row(&[
            spec.name.to_string(),
            nn.to_string(),
            spec.d.to_string(),
            fmt_bytes(simplex_bytes),
            fmt_bytes(skip_bytes),
            format!("{:.1}x", skip_bytes as f64 / simplex_bytes as f64),
            fmt_bytes(skip_full as usize),
        ]);
    }
    println!("\nFig. 5 — method working-set memory (exact accounting), rank-100 SKIP\n");
    table.print();
    table.write_csv("fig5_memory");
    println!(
        "\nProcess peak RSS: {}\nShape check (paper): Simplex-GP's memory sits well below SKIP's, and the\nfull-n extrapolation shows why SKIP OOMs on Houseelectric (the paper's 24 GB).\n",
        fmt_bytes(simplex_gp::util::mem::peak_rss())
    );
}

//! Fig. 7 — training instability vs CG tolerance: per-epoch train MLL
//! and validation RMSE curves at train tolerance 1.0 (the paper's
//! default, non-monotonic) vs 1e-4 (stable but slow). Emits the curves
//! as CSV and prints a monotonicity summary.

use simplex_gp::datasets::{generate, split_standardize};
use simplex_gp::gp::{train, SolveMode, TrainConfig};
use simplex_gp::kernels::KernelFamily;
use simplex_gp::util::bench::Table;

fn run_curve(
    sp: &simplex_gp::datasets::Split,
    d: usize,
    tol: f64,
    epochs: usize,
) -> Vec<(usize, f64, f64)> {
    let cfg = TrainConfig {
        epochs,
        probes: 6,
        solve: SolveMode::Cg { tol },
        track_mll: true,
        patience: epochs + 1,
        // Ill-conditioned start — the regime where loose CG destabilizes
        // training (paper §5.4 / Appendix B).
        init_noise: 1e-3,
        min_noise: 1e-4,
        ..TrainConfig::default()
    };
    let out = train(
        &sp.train.x,
        &sp.train.y,
        &sp.val.x,
        &sp.val.y,
        d,
        KernelFamily::Matern32,
        cfg,
    )
    .unwrap();
    out.records
        .iter()
        .map(|r| (r.epoch, r.mll.unwrap_or(f64::NAN), r.val_rmse))
        .collect()
}

fn non_monotonic_steps(mlls: &[f64]) -> usize {
    mlls.windows(2).filter(|w| w[1] < w[0] - 1e-9).count()
}

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let n = if quick { 1200 } else { 6000 };
    let epochs = if quick { 6 } else { 20 };
    // keggdirected is the dataset the paper shows in Fig. 7.
    let ds = generate("keggdirected", n, 0);
    let sp = split_standardize(&ds, 1);
    let d = 20;

    let mut table = Table::new(&["epoch", "mll_tol1.0", "rmse_tol1.0", "mll_tol1e-4", "rmse_tol1e-4"]);
    let loose = run_curve(&sp, d, 1.0, epochs);
    let tight = run_curve(&sp, d, 1e-4, epochs);
    for i in 0..loose.len().min(tight.len()) {
        table.row(&[
            loose[i].0.to_string(),
            format!("{:.2}", loose[i].1),
            format!("{:.4}", loose[i].2),
            format!("{:.2}", tight[i].1),
            format!("{:.4}", tight[i].2),
        ]);
    }
    println!("\nFig. 7 — training curves on keggdirected analog (n = {n})\n");
    table.print();
    table.write_csv("fig7_instability");

    let loose_mll: Vec<f64> = loose.iter().map(|r| r.1).collect();
    let tight_mll: Vec<f64> = tight.iter().map(|r| r.1).collect();
    println!(
        "\nnon-monotonic MLL steps: tol 1.0 -> {} / {}, tol 1e-4 -> {} / {}",
        non_monotonic_steps(&loose_mll),
        loose_mll.len() - 1,
        non_monotonic_steps(&tight_mll),
        tight_mll.len() - 1
    );
    println!("Shape check (paper): the loose-tolerance curve is visibly less monotone.\n");
}

//! Table 3 — lattice sparsity: lattice points m generated per dataset
//! vs the worst case L = n·(d+1). Paper's measured ratios are listed
//! alongside for the shape comparison.

use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::util::bench::Table;

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let mut table = Table::new(&["dataset", "n", "d", "m", "m/L", "paper_m/L"]);
    for spec in PAPER_DATASETS {
        let n = if quick { 2000 } else { spec.n_default };
        let ds = generate(spec.name, n, 0);
        let sp = split_standardize(&ds, 1);
        let k = ArdKernel::with_lengthscale(KernelFamily::Rbf, spec.d, 1.0);
        let lat = PermutohedralLattice::build(&sp.train.x, spec.d, &k, 1);
        table.row(&[
            spec.name.to_string(),
            lat.n.to_string(),
            spec.d.to_string(),
            lat.m.to_string(),
            format!("{:.3}", lat.sparsity_ratio()),
            format!("{:.3}", spec.paper_sparsity),
        ]);
    }
    println!("\nTable 3 — lattice points generated vs worst case L = n(d+1)\n");
    table.print();
    table.write_csv("table3_sparsity");
    println!("\nShape check (paper): precipitation ~ 1e-3, houseelectric/protein a few\npercent, keggdirected ~ 0.1, elevators the outlier near 0.7.\n");
}

//! Serving-path tail latency under open-loop load (the PR-6 bench).
//!
//! Drives a live coordinator with the [`loadgen`] harness across eight
//! deployment shapes:
//!
//!   inproc           in-process shard pool, serving-shaped mix
//!   tcp              2 remote shard workers (loopback), bin1 frames
//!   tcp_json         same cluster forced onto v1 JSON frames — the
//!                    bin1-vs-JSON wire-encoding comparison pair
//!   tcp_slow         2 workers, worker 0 delayed `slow_ms` per MVM
//!                    roundtrip (injected straggler), hedging OFF
//!   tcp_slow_hedged  same straggler, hedging ON (`hedge_ms` race to
//!                    the backup replica)
//!   tcp_var          2 workers, every predict asks for variance —
//!                    cross-covariance columns realized per shard
//!   tcp_var_shed     same variance traffic with `shed_shards` on: the
//!                    coordinator holds no shard lattices and the
//!                    columns come back from the worker replicas (the
//!                    shed-vs-unshed variance serving comparison pair;
//!                    byte-identity is pinned by rust/tests/shed_mode.rs)
//!   tcp_rebalance    serving mix measured while a dedicated driver
//!                    streams skewed ingest until the background shard
//!                    rebalance commits mid-window — the row's p99 is
//!                    the tail with the write-locked swap inside it,
//!                    and `rebalances` records that it actually fired
//!                    (byte-identity across the swap is pinned by
//!                    rust/tests/rebalance.rs)
//!
//! The straggler rows are the point: an injected straggler wrecks p99
//! on an unhedged cluster and the hedge race claws it back, while the
//! replies stay byte-identical (pinned by rust/tests/hedging.rs; this
//! bench measures, the test asserts). The tcp/tcp_json pair puts a
//! number on what the protocol-v2 binary payloads buy at serving load
//! (byte-identity across encodings is pinned by
//! rust/tests/protocol_conformance.rs).
//!
//! Latency is open-loop (measured from *scheduled* arrival), so
//! queueing behind the straggler counts against the tail — no
//! coordinated omission.
//!
//! With `SIMPLEX_GP_BENCH_JSON=<path>` set (CI bench-smoke), one line
//! per mode: `{"bench":"serving_load", "mode", "encoding", "workers",
//! "shards", "hedge_ms", "slow_ms", "rps", "sent", "ok", "errors",
//! "achieved_rps", "p50_us", "p90_us", "p99_us", "p999_us", "max_us",
//! "hedged", "hedge_wins", "shed", "variance", "shed_rebuilds",
//! "rebalances"}`.
//!
//!     cargo bench --bench serving_load [-- --quick]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use simplex_gp::coordinator::frame::WireEncoding;
use simplex_gp::coordinator::transport::ClusterConfig;
use simplex_gp::coordinator::worker::{ShardWorker, WorkerConfig};
use simplex_gp::coordinator::{Client, ServeConfig, Server};
use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::loadgen::{self, Arrival, LoadSpec, Mix};
use simplex_gp::util::bench::{append_bench_json, quick_mode, Table};
use simplex_gp::util::json::Json;
use simplex_gp::util::Pcg64;

struct Scenario {
    mode: &'static str,
    workers: usize,
    slow_ms: u64,
    hedge_ms: u64,
    encoding: WireEncoding,
    /// `[cluster] shed_shards`: fully worker-resident serving.
    shed: bool,
    /// Arm `[cluster] rebalance_skew` and stream skewed ingest from a
    /// side driver so a background shard rebalance commits mid-window.
    rebalance: bool,
    spec: LoadSpec,
}

/// Stream deliberately skewed ingest batches (far-spread / tight
/// clusters alternating, as in rust/tests/rebalance.rs) until the
/// coordinator reports a committed rebalance or the window closes.
fn drive_rebalance_skew(
    addr: std::net::SocketAddr,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
) -> std::thread::JoinHandle<()> {
    use std::sync::atomic::Ordering;
    std::thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        let mut rng = Pcg64::new(0xbe6d);
        for step in 0..600 {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let scale = if step % 2 == 0 { 10.0 } else { 0.1 };
            let rows = 6;
            let x: Vec<f64> = (0..rows * 2).map(|_| rng.uniform_in(-scale, scale)).collect();
            let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
            if client.ingest(&x, &y, 2).is_err() {
                return;
            }
            let rebalanced = client
                .stats()
                .ok()
                .and_then(|s| s.get("rebalances").and_then(|v| v.as_f64()))
                .unwrap_or(0.0);
            if rebalanced >= 1.0 {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    })
}

fn fit_model(n: usize, d: usize, shards: usize, seed: u64) -> SimplexGp {
    let mut rng = Pcg64::new(seed);
    let x: Vec<f64> = (0..n * d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| (x[i * d]).sin() + 0.05 * rng.normal())
        .collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let cfg = GpConfig {
        shards,
        ..GpConfig::default()
    };
    SimplexGp::fit(&x, &y, d, kernel, 0.05, cfg).unwrap()
}

/// Inject a per-roundtrip delay on the worker link serving `shard`
/// (raw request — the op is debug-only and gated by `debug_ops`).
fn inject_straggler(addr: &std::net::SocketAddr, shard: usize, delay_ms: u64) {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{{\"id\":7,\"op\":\"debug_delay_worker\",\"shard\":{shard},\"delay_ms\":{delay_ms}}}"
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"delayed\":1"), "straggler injection failed: {line}");
}

fn wait_remote_synced(addr: &std::net::SocketAddr, want: usize) {
    let mut client = Client::connect(addr).unwrap();
    let t0 = Instant::now();
    loop {
        let got = client
            .stats()
            .unwrap()
            .get("remote_workers")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0) as i64;
        if got == want as i64 {
            return;
        }
        assert!(
            t0.elapsed().as_secs() < 30,
            "remote workers never synced: {got}/{want}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn main() {
    let quick = quick_mode();
    let d = 2;
    let shards = 2;
    let n = if quick { 400 } else { 800 };

    let serving_spec = |rps: f64, secs: f64| LoadSpec {
        rps,
        duration: Duration::from_secs_f64(secs),
        clients: 8,
        arrival: Arrival::Poisson,
        mix: Mix::serving(),
        ..LoadSpec::default()
    };
    // Straggler rows use pure-MVM bursty traffic: every request crosses
    // the delayed link, so the tail shows the injected fault, not the
    // mix.
    let slow_spec = |rps: f64, secs: f64| LoadSpec {
        rps,
        duration: Duration::from_secs_f64(secs),
        clients: 8,
        arrival: Arrival::Bursty {
            period: Duration::from_millis(200),
            on_fraction: 0.5,
        },
        mix: Mix::mvm_only(),
        ..LoadSpec::default()
    };
    let (rps, secs) = if quick { (150.0, 1.2) } else { (250.0, 3.0) };
    let (slow_rps, slow_secs) = if quick { (50.0, 1.0) } else { (80.0, 2.0) };
    let slow_ms: u64 = if quick { 200 } else { 300 };

    // Variance rows: same serving-shaped mix, every predict asks for
    // the predictive variance as well.
    let var_spec = |rps: f64, secs: f64| LoadSpec {
        predict_variance: true,
        ..serving_spec(rps, secs)
    };
    let (var_rps, var_secs) = if quick { (60.0, 1.0) } else { (100.0, 2.0) };

    let scenarios = [
        Scenario {
            mode: "inproc",
            workers: 0,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: false,
            spec: serving_spec(rps, secs),
        },
        Scenario {
            mode: "tcp",
            workers: 2,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: false,
            spec: serving_spec(rps, secs),
        },
        Scenario {
            mode: "tcp_json",
            workers: 2,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Json,
            shed: false,
            rebalance: false,
            spec: serving_spec(rps, secs),
        },
        Scenario {
            mode: "tcp_slow",
            workers: 2,
            slow_ms,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: false,
            spec: slow_spec(slow_rps, slow_secs),
        },
        Scenario {
            mode: "tcp_slow_hedged",
            workers: 2,
            slow_ms,
            hedge_ms: 25,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: false,
            spec: slow_spec(slow_rps, slow_secs),
        },
        Scenario {
            mode: "tcp_var",
            workers: 2,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: false,
            spec: var_spec(var_rps, var_secs),
        },
        Scenario {
            mode: "tcp_var_shed",
            workers: 2,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: true,
            rebalance: false,
            spec: var_spec(var_rps, var_secs),
        },
        Scenario {
            mode: "tcp_rebalance",
            workers: 2,
            slow_ms: 0,
            hedge_ms: 0,
            encoding: WireEncoding::Bin1,
            shed: false,
            rebalance: true,
            spec: serving_spec(rps, secs),
        },
    ];

    let mut table = Table::new(&[
        "mode",
        "enc",
        "workers",
        "rps",
        "ok",
        "errors",
        "p50_ms",
        "p99_ms",
        "p999_ms",
        "achieved",
        "hedged",
        "hedge_wins",
    ]);

    for sc in &scenarios {
        let workers: Vec<ShardWorker> = (0..sc.workers)
            .map(|_| {
                ShardWorker::start(WorkerConfig {
                    listen: "127.0.0.1:0".to_string(),
                    ..WorkerConfig::default()
                })
                .unwrap()
            })
            .collect();
        // The rebalance row arms the skew threshold just above the
        // fitted model's initial skew, so the driver's spread batches
        // cross it quickly and the swap lands inside the window.
        let rebalance_skew = if sc.rebalance {
            let skew = fit_model(n, d, shards, 0xbe6c)
                .skew_pair()
                .map(|(_, _, s)| s)
                .unwrap_or(1.0);
            (skew * 1.1).max(1.3)
        } else {
            0.0
        };
        let cluster = ClusterConfig {
            workers: workers.iter().map(|w| w.local_addr.to_string()).collect(),
            hedge: match sc.hedge_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            encoding: sc.encoding,
            shed_shards: sc.shed,
            rebalance_skew,
            ..ClusterConfig::default()
        };
        let server = Server::start(
            fit_model(n, d, shards, 0xbe6c),
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                allow_ingest: true,
                debug_ops: sc.slow_ms > 0,
                cluster,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        if sc.workers > 0 {
            wait_remote_synced(&server.local_addr, sc.workers.min(shards));
        }
        if sc.slow_ms > 0 {
            inject_straggler(&server.local_addr, 0, sc.slow_ms);
        }

        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let driver = sc
            .rebalance
            .then(|| drive_rebalance_skew(server.local_addr, stop.clone()));

        let report = loadgen::run(&server.local_addr, &sc.spec).unwrap();

        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(handle) = driver {
            let _ = handle.join();
        }
        if sc.rebalance {
            // Grace poll: the commit is asynchronous, so give a build
            // that crossed the threshold late in the window a moment to
            // land before recording the row.
            let t0 = Instant::now();
            while server.rebalances() == 0 && t0.elapsed().as_secs() < 10 {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        let rebalances = server.rebalances();

        let mut stats_client = Client::connect(&server.local_addr).unwrap();
        let stats = stats_client.stats().unwrap();
        let hedged = stats.get("hedged").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let hedge_wins = stats
            .get("hedge_wins")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        drop(stats_client);
        let shed_rebuilds = server.shed_rebuilds();
        server.shutdown();
        for w in workers {
            w.shutdown();
        }

        let (p50, p90, p99, p999) = report.hist.quartet();
        table.row(&[
            sc.mode.to_string(),
            sc.encoding.as_str().to_string(),
            sc.workers.to_string(),
            format!("{:.0}", sc.spec.rps),
            report.ok.to_string(),
            report.errors.to_string(),
            format!("{:.3}", p50 / 1e3),
            format!("{:.3}", p99 / 1e3),
            format!("{:.3}", p999 / 1e3),
            format!("{:.0}", report.achieved_rps),
            format!("{hedged:.0}"),
            format!("{hedge_wins:.0}"),
        ]);

        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str("serving_load".to_string()));
        obj.insert("mode".to_string(), Json::Str(sc.mode.to_string()));
        obj.insert(
            "encoding".to_string(),
            Json::Str(sc.encoding.as_str().to_string()),
        );
        for (k, v) in [
            ("workers", sc.workers as f64),
            ("shards", shards as f64),
            ("hedge_ms", sc.hedge_ms as f64),
            ("slow_ms", sc.slow_ms as f64),
            ("rps", sc.spec.rps),
            ("sent", report.sent as f64),
            ("ok", report.ok as f64),
            ("errors", report.errors as f64),
            ("achieved_rps", report.achieved_rps),
            ("p50_us", p50),
            ("p90_us", p90),
            ("p99_us", p99),
            ("p999_us", p999),
            ("max_us", report.hist.max_us()),
            ("hedged", hedged),
            ("hedge_wins", hedge_wins),
            ("shed", sc.shed as u8 as f64),
            ("variance", sc.spec.predict_variance as u8 as f64),
            ("shed_rebuilds", shed_rebuilds as f64),
            ("rebalances", rebalances as f64),
        ] {
            obj.insert(k.to_string(), Json::Num(v));
        }
        append_bench_json(&Json::Obj(obj));
    }

    println!(
        "Open-loop serving load: n = {n}, d = {d}, P = {shards} \
         (straggler = {slow_ms} ms on worker 0{})\n",
        if quick { ", quick" } else { "" }
    );
    table.print();
    table.write_csv("serving_load");
}

//! Table 1 — MVM time complexity, verified empirically: fit log-log
//! scaling exponents of MVM wall time vs n for Exact (O(n²)), KISS-GP
//! (O(n·2^d) — n-linear with a 2^d constant), SKIP (O(rnd)) and
//! Simplex-GP (O(nd²)).

use simplex_gp::baselines::{KissGpMvm, SkipMvm};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{ExactMvm, MvmOperator, SimplexMvm};
use simplex_gp::util::bench::{append_bench_json, bench_record, fmt_secs, time_budget, Table};
use simplex_gp::util::stats::loglog_slope;
use simplex_gp::util::Pcg64;

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let d = 4;
    let sizes: Vec<usize> = if quick {
        vec![512, 1024, 2048]
    } else {
        vec![1024, 2048, 4096, 8192, 16384]
    };
    let budget = if quick { 0.2 } else { 1.0 };
    let mut rng = Pcg64::new(2);
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);

    let mut table = Table::new(&["n", "exact", "kissgp", "skip_r30", "simplex"]);
    let mut times: [Vec<f64>; 4] = [vec![], vec![], vec![], vec![]];
    let ns: Vec<f64> = sizes.iter().map(|&n| n as f64).collect();
    for &n in &sizes {
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let v = rng.normal_vec(n);
        let exact = ExactMvm::new(&kernel, &x, d);
        let kiss = KissGpMvm::build(&x, d, &kernel, 10).unwrap();
        let skip = SkipMvm::build(&x, d, &kernel, 30, 3).unwrap();
        let simplex = SimplexMvm::build(&x, d, &kernel, 1);
        let te = time_budget("exact", budget, 50, || exact.mvm(&v));
        let tk = time_budget("kiss", budget, 50, || kiss.mvm(&v));
        let ts = time_budget("skip", budget, 50, || skip.mvm(&v));
        let tx = time_budget("simplex", budget, 50, || simplex.mvm(&v));
        times[0].push(te.median_s);
        times[1].push(tk.median_s);
        times[2].push(ts.median_s);
        times[3].push(tx.median_s);
        // Perf-trajectory records (CI bench-smoke → BENCH_PR3.json).
        for (op, t) in [("exact", &te), ("kissgp", &tk), ("skip", &ts), ("simplex", &tx)] {
            let mut rec = bench_record(
                "table1_mvm_scaling",
                &[
                    ("n", n as f64),
                    ("d", d as f64),
                    ("B", 1.0),
                    ("shards", 1.0),
                    ("ns_per_mvm", t.median_s * 1e9),
                ],
            );
            if let simplex_gp::util::json::Json::Obj(map) = &mut rec {
                map.insert(
                    "op".to_string(),
                    simplex_gp::util::json::Json::Str(op.to_string()),
                );
            }
            append_bench_json(&rec);
        }
        table.row(&[
            n.to_string(),
            fmt_secs(te.median_s),
            fmt_secs(tk.median_s),
            fmt_secs(ts.median_s),
            fmt_secs(tx.median_s),
        ]);
    }
    println!("\nTable 1 — one-MVM wall time vs n (d = {d})\n");
    table.print();
    table.write_csv("table1_mvm_scaling");

    let labels = ["exact", "kissgp", "skip", "simplex"];
    let paper = [
        "O(n^2) => slope 2",
        "O(n 2^d) => slope 1",
        "O(rnd) => slope 1",
        "O(n d^2) => slope 1",
    ];
    println!("\nEmpirical log-log scaling exponents (paper's Table 1 claim):");
    for i in 0..4 {
        let slope = loglog_slope(&ns, &times[i]);
        println!("  {:<8} slope {:+.2}   [{}]", labels[i], slope, paper[i]);
    }
    println!();
}

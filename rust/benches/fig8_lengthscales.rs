//! Fig. 8 — learned ARD lengthscales: Simplex-GP vs Exact GP on each
//! benchmark. The paper's claim is qualitative agreement of the
//! *relevance ordering* (and often the values); we train both with the
//! same protocol and report the per-dimension lengthscales plus the
//! Spearman rank correlation between the two orderings.

use simplex_gp::baselines::ExactGp;
use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::gp::{train, TrainConfig};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{ExactMvm, MvmOperator, Shifted};
use simplex_gp::solvers::{cg_multi, CgOptions};
use simplex_gp::util::bench::Table;
use simplex_gp::util::Pcg64;

/// Train exact-GP hyperparameters with the same Adam/BBMM protocol as
/// the Simplex trainer, but with exact MVMs (subsampled for cost).
fn train_exact_ard(
    x: &[f64],
    y: &[f64],
    d: usize,
    epochs: usize,
    seed: u64,
) -> (Vec<f64>, f64, f64) {
    let n = y.len();
    let mut rng = Pcg64::new(seed);
    let mut params = vec![0.0f64; d + 2];
    params[d + 1] = (0.1f64).ln();
    let mut m = vec![0.0; d + 2];
    let mut v = vec![0.0; d + 2];
    for t in 1..=epochs {
        let ls: Vec<f64> = params[..d].iter().map(|p| p.exp().clamp(1e-3, 1e3)).collect();
        let s2 = params[d].exp().clamp(1e-4, 1e4);
        let noise = 1e-4 + params[d + 1].exp().clamp(0.0, 1e3);
        let mut kernel = ArdKernel::new(KernelFamily::Matern32, d);
        kernel.lengthscales = ls.clone();
        kernel.outputscale = s2;
        let op = ExactMvm::new(&kernel, x, d);
        let shifted = Shifted::new(&op, noise);
        let p = 4usize;
        let probes: Vec<Vec<f64>> = (0..p).map(|_| rng.rademacher_vec(n)).collect();
        let nc = p + 1;
        let mut rhs = vec![0.0; n * nc];
        for i in 0..n {
            rhs[i * nc] = y[i];
            for (k, z) in probes.iter().enumerate() {
                rhs[i * nc + 1 + k] = z[i];
            }
        }
        let (sol, _) = cg_multi(
            &shifted,
            &rhs,
            nc,
            CgOptions {
                tol: 0.1,
                max_iters: 200,
                min_iters: 10,
            },
        );
        let alpha: Vec<f64> = (0..n).map(|i| sol[i * nc]).collect();
        // Gradients by the exact bilinear forms (O(n² d) per epoch —
        // this is why it's subsampled).
        let mut g = vec![0.0; d + 2];
        // noise grad
        let mut tr = 0.0;
        for (k, z) in probes.iter().enumerate() {
            let sz: Vec<f64> = (0..n).map(|i| sol[i * nc + 1 + k]).collect();
            tr += simplex_gp::util::stats::dot(z, &sz);
        }
        g[d + 1] = (0.5 * simplex_gp::util::stats::dot(&alpha, &alpha) - 0.5 * tr / p as f64)
            * (noise - 1e-4);
        // outputscale + lengthscale grads via explicit pair sums.
        let pairs: Vec<(Vec<f64>, Vec<f64>, f64)> = {
            let mut v = vec![(alpha.clone(), alpha.clone(), 0.5)];
            for (k, z) in probes.iter().enumerate() {
                let sz: Vec<f64> = (0..n).map(|i| sol[i * nc + 1 + k]).collect();
                v.push((sz, z.clone(), -0.5 / p as f64));
            }
            v
        };
        for (gv, vv, w) in &pairs {
            for i in 0..n {
                let xi = &x[i * d..(i + 1) * d];
                for j in 0..n {
                    let xj = &x[j * d..(j + 1) * d];
                    let r2 = kernel.scaled_r2(xi, xj);
                    let kij = kernel.family.profile(r2);
                    g[d] += w * gv[i] * vv[j] * kij * s2; // d/d log s2
                    let kp = kernel.family.profile_deriv(r2);
                    for dim in 0..d {
                        let diff = (xi[dim] - xj[dim]) / ls[dim];
                        // d r2 / d log ell = -2 diff^2
                        g[dim] += w * gv[i] * vv[j] * s2 * kp * (-2.0 * diff * diff);
                    }
                }
            }
        }
        for j in 0..d + 2 {
            if !g[j].is_finite() {
                g[j] = 0.0;
            }
            m[j] = 0.9 * m[j] + 0.1 * g[j];
            v[j] = 0.999 * v[j] + 0.001 * g[j] * g[j];
            let mh = m[j] / (1.0 - 0.9f64.powi(t as i32));
            let vh = v[j] / (1.0 - 0.999f64.powi(t as i32));
            params[j] += 0.1 * mh / (vh.sqrt() + 1e-8);
        }
    }
    let ls: Vec<f64> = params[..d].iter().map(|p| p.exp()).collect();
    (
        ls,
        params[d].exp(),
        1e-4 + params[d + 1].exp(),
    )
}

fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let rank = |xs: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
        let mut r = vec![0.0; xs.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos as f64;
        }
        r
    };
    let ra = rank(a);
    let rb = rank(b);
    let n = a.len() as f64;
    let d2: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - y) * (x - y)).sum();
    1.0 - 6.0 * d2 / (n * (n * n - 1.0)).max(1.0)
}

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let n_simplex = if quick { 1200 } else { 4000 };
    let n_exact = if quick { 400 } else { 1000 }; // exact-grad epochs are O(n²d)
    let epochs = if quick { 6 } else { 15 };

    let mut table = Table::new(&["dataset", "dim", "ell_simplex", "ell_exact"]);
    let mut summary = Table::new(&["dataset", "spearman_rho"]);
    for spec in PAPER_DATASETS {
        // keggdirected/elevators at full d make the exact-grad loop slow;
        // still fine at these n.
        let ds = generate(spec.name, n_simplex.min(spec.n_default), 0);
        let sp = split_standardize(&ds, 1);
        let d = spec.d;
        let cfg = TrainConfig {
            epochs,
            probes: 6,
            ..TrainConfig::default()
        };
        let out = train(
            &sp.train.x,
            &sp.train.y,
            &sp.val.x,
            &sp.val.y,
            d,
            KernelFamily::Matern32,
            cfg,
        )
        .unwrap();
        let ls_simplex = out.model.kernel.lengthscales.clone();
        let ne = n_exact.min(sp.train.n());
        let (ls_exact, _, _) =
            train_exact_ard(&sp.train.x[..ne * d], &sp.train.y[..ne], d, epochs, 3);
        for j in 0..d {
            table.row(&[
                spec.name.to_string(),
                format!("l{j}"),
                format!("{:.3}", ls_simplex[j]),
                format!("{:.3}", ls_exact[j]),
            ]);
        }
        summary.row(&[
            spec.name.to_string(),
            format!("{:.3}", spearman(&ls_simplex, &ls_exact)),
        ]);
        println!("[fig8] finished {}", spec.name);
    }
    println!("\nFig. 8 — learned ARD lengthscales, Simplex-GP vs Exact GP\n");
    table.write_csv("fig8_lengthscales");
    summary.print();
    summary.write_csv("fig8_spearman");
    println!("\nShape check (paper): relevance orderings agree (positive rank\ncorrelation); absolute values may differ via the outputscale trade-off.\n");
}

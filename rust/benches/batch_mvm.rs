//! Batched multi-RHS MVM throughput: one `b × n` block pass through
//! the lattice vs `b` sequential single-RHS MVMs (the acceptance
//! benchmark for the block engine: B = 8 must beat 8 sequential MVMs
//! by ≥ 2×), plus the same comparison for block-CG, where every Krylov
//! iteration shares one lattice traversal across all right-hand sides,
//! plus the PR-2 shard-scaling sweep: single-request MVM wall time vs
//! shard count P on n = 50k (acceptance: ≥ 1.5× at P = 4).
//!
//! With `SIMPLEX_GP_BENCH_JSON=<path>` set (CI bench-smoke), every row
//! is appended to the perf-trajectory JSON file as
//! `{"bench", "n", "d", "B", "shards", "ns_per_mvm"}` records.
//!
//!     cargo bench --bench batch_mvm [-- --quick]

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::ShardedLattice;
use simplex_gp::mvm::{MvmOperator, Shifted, SimplexMvm};
use simplex_gp::solvers::{cg, cg_block, CgOptions};
use simplex_gp::util::bench::{
    append_bench_json, bench_record, fmt_secs, quick_mode, time_budget, Table,
};
use simplex_gp::util::Pcg64;

fn main() {
    let quick = quick_mode();
    let d = 4;
    let n: usize = if quick { 4_096 } else { 32_768 };
    let budget = if quick { 0.3 } else { 1.5 };
    let mut rng = Pcg64::new(7);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let op = SimplexMvm::build(&x, d, &kernel, 1);
    println!(
        "lattice: n = {n}, d = {d}, m = {} ({} threads)\n",
        op.lattice.m,
        simplex_gp::util::parallel::num_threads()
    );

    // --- MVM throughput: sequential singles vs one block pass ---
    let mut table = Table::new(&[
        "B",
        "sequential",
        "block",
        "speedup",
        "RHS/s (block)",
    ]);
    for &b in &[1usize, 8, 32] {
        let v = rng.normal_vec(n * b);
        let seq = time_budget(&format!("seq b={b}"), budget, 50, || {
            let mut out = Vec::with_capacity(n * b);
            for c in 0..b {
                out.extend_from_slice(&op.mvm(&v[c * n..(c + 1) * n]));
            }
            out
        });
        let blk = time_budget(&format!("block b={b}"), budget, 50, || op.mvm_block(&v, b));
        let speedup = seq.median_s / blk.median_s.max(1e-12);
        table.row(&[
            b.to_string(),
            fmt_secs(seq.median_s),
            fmt_secs(blk.median_s),
            format!("{speedup:.2}x"),
            format!("{:.0}", b as f64 / blk.median_s.max(1e-12)),
        ]);
        append_bench_json(&bench_record(
            "batch_mvm",
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("B", b as f64),
                ("shards", 1.0),
                ("ns_per_mvm", blk.median_s * 1e9 / b as f64),
            ],
        ));
        if b == 8 {
            println!(
                "acceptance: B=8 block vs 8 sequential MVMs = {speedup:.2}x {}",
                if speedup >= 2.0 { "(>= 2x: PASS)" } else { "(< 2x: FAIL)" }
            );
        }
    }
    println!("\nBatched MVM — one splat->blur->slice pass for all B RHS\n");
    table.print();
    table.write_csv("batch_mvm");

    // --- PR-2 shard scaling: single-request MVM wall time vs P ---
    // n stays at 50k even in quick mode: the acceptance target is
    // single-request latency improving with shards on n >= 50k
    // (>= 1.5x at P = 4). Points are sorted along the first coordinate
    // so the contiguous row ranges become spatial slabs — the locality
    // assumption contiguous-range sharding is designed around
    // (ARCHITECTURE.md §Sharding): spatially disjoint shards keep
    // Σ_p m_p ≈ m, so the blur work is conserved while the serial splat
    // scatter and the per-shard traversals run P-way concurrent.
    let shard_n: usize = 50_000;
    let shard_d = 4;
    let shard_budget = if quick { 0.4 } else { 2.0 };
    let xs: Vec<f64> = {
        let mut r = Pcg64::new(11);
        let raw: Vec<f64> = (0..shard_n * shard_d).map(|_| r.normal()).collect();
        let mut order: Vec<usize> = (0..shard_n).collect();
        order.sort_by(|&a, &b| raw[a * shard_d].total_cmp(&raw[b * shard_d]));
        let mut sorted = Vec::with_capacity(shard_n * shard_d);
        for i in order {
            sorted.extend_from_slice(&raw[i * shard_d..(i + 1) * shard_d]);
        }
        sorted
    };
    let vs = {
        let mut r = Pcg64::new(12);
        r.normal_vec(shard_n)
    };
    let mut shard_table = Table::new(&["P", "build", "one MVM", "speedup vs P=1"]);
    let mut base_mvm_s = 0.0;
    for &p in &[1usize, 2, 4] {
        let t0 = std::time::Instant::now();
        let lat = ShardedLattice::build(&xs, shard_d, &kernel, 1, p);
        let build_s = t0.elapsed().as_secs_f64();
        let t = time_budget(&format!("shard p={p}"), shard_budget, 30, || lat.mvm(&vs));
        if p == 1 {
            base_mvm_s = t.median_s;
        }
        let speedup = base_mvm_s / t.median_s.max(1e-12);
        shard_table.row(&[
            p.to_string(),
            fmt_secs(build_s),
            fmt_secs(t.median_s),
            format!("{speedup:.2}x"),
        ]);
        append_bench_json(&bench_record(
            "shard_mvm",
            &[
                ("n", shard_n as f64),
                ("d", shard_d as f64),
                ("B", 1.0),
                ("shards", p as f64),
                ("ns_per_mvm", t.median_s * 1e9),
            ],
        ));
        if p == 4 {
            println!(
                "acceptance: P=4 sharded vs single-lattice MVM = {speedup:.2}x {}",
                if speedup >= 1.5 {
                    "(>= 1.5x: PASS)"
                } else {
                    "(< 1.5x: FAIL)"
                }
            );
        }
    }
    println!(
        "\nShard scaling — one MVM, n = {shard_n}, d = {shard_d} ({} threads)\n",
        simplex_gp::util::parallel::num_threads()
    );
    shard_table.print();
    shard_table.write_csv("shard_mvm");

    // --- Block-CG: probes + target solved in one Krylov run ---
    let noise = 0.1;
    let sym = SimplexMvm::build(&x, d, &kernel, 1).with_symmetrize(true);
    let shifted = Shifted::new(&sym, noise);
    let nrhs = 8;
    let rhs = rng.normal_vec(n * nrhs);
    let opts = CgOptions {
        tol: 1e-4,
        max_iters: 200,
        min_iters: 1,
    };
    let mut cg_table = Table::new(&["solver", "time", "iterations"]);
    let seq = time_budget("cg sequential", budget, 10, || {
        let mut worst = 0usize;
        for c in 0..nrhs {
            let r = cg(&shifted, &rhs[c * n..(c + 1) * n], opts);
            worst = worst.max(r.iterations);
        }
        worst
    });
    let blk = time_budget("cg block", budget, 10, || {
        cg_block(&shifted, &rhs, nrhs, opts).iterations
    });
    let iters = cg_block(&shifted, &rhs, nrhs, opts).iterations;
    cg_table.row(&[
        format!("{nrhs} sequential CG solves"),
        fmt_secs(seq.median_s),
        iters.to_string(),
    ]);
    cg_table.row(&[
        format!("block-CG ({nrhs} RHS)"),
        fmt_secs(blk.median_s),
        iters.to_string(),
    ]);
    println!(
        "\nBlock-CG vs sequential CG (B = {nrhs}, tol = {:.0e}) — speedup {:.2}x\n",
        opts.tol,
        seq.median_s / blk.median_s.max(1e-12)
    );
    cg_table.print();
    cg_table.write_csv("batch_cg");
    println!();
}

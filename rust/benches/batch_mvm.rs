//! Batched multi-RHS MVM throughput: one `b × n` block pass through
//! the lattice vs `b` sequential single-RHS MVMs (the acceptance
//! benchmark for the block engine: B = 8 must beat 8 sequential MVMs
//! by ≥ 2×), plus the same comparison for block-CG, where every Krylov
//! iteration shares one lattice traversal across all right-hand sides.
//!
//!     cargo bench --bench batch_mvm [-- --quick]

use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::mvm::{MvmOperator, Shifted, SimplexMvm};
use simplex_gp::solvers::{cg, cg_block, CgOptions};
use simplex_gp::util::bench::{fmt_secs, quick_mode, time_budget, Table};
use simplex_gp::util::Pcg64;

fn main() {
    let quick = quick_mode();
    let d = 4;
    let n: usize = if quick { 4_096 } else { 32_768 };
    let budget = if quick { 0.3 } else { 1.5 };
    let mut rng = Pcg64::new(7);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 1.0);
    let op = SimplexMvm::build(&x, d, &kernel, 1);
    println!(
        "lattice: n = {n}, d = {d}, m = {} ({} threads)\n",
        op.lattice.m,
        simplex_gp::util::parallel::num_threads()
    );

    // --- MVM throughput: sequential singles vs one block pass ---
    let mut table = Table::new(&[
        "B",
        "sequential",
        "block",
        "speedup",
        "RHS/s (block)",
    ]);
    for &b in &[1usize, 8, 32] {
        let v = rng.normal_vec(n * b);
        let seq = time_budget(&format!("seq b={b}"), budget, 50, || {
            let mut out = Vec::with_capacity(n * b);
            for c in 0..b {
                out.extend_from_slice(&op.mvm(&v[c * n..(c + 1) * n]));
            }
            out
        });
        let blk = time_budget(&format!("block b={b}"), budget, 50, || op.mvm_block(&v, b));
        let speedup = seq.median_s / blk.median_s.max(1e-12);
        table.row(&[
            b.to_string(),
            fmt_secs(seq.median_s),
            fmt_secs(blk.median_s),
            format!("{speedup:.2}x"),
            format!("{:.0}", b as f64 / blk.median_s.max(1e-12)),
        ]);
        if b == 8 {
            println!(
                "acceptance: B=8 block vs 8 sequential MVMs = {speedup:.2}x {}",
                if speedup >= 2.0 { "(>= 2x: PASS)" } else { "(< 2x: FAIL)" }
            );
        }
    }
    println!("\nBatched MVM — one splat->blur->slice pass for all B RHS\n");
    table.print();
    table.write_csv("batch_mvm");

    // --- Block-CG: probes + target solved in one Krylov run ---
    let noise = 0.1;
    let sym = SimplexMvm::build(&x, d, &kernel, 1).with_symmetrize(true);
    let shifted = Shifted::new(&sym, noise);
    let nrhs = 8;
    let rhs = rng.normal_vec(n * nrhs);
    let opts = CgOptions {
        tol: 1e-4,
        max_iters: 200,
        min_iters: 1,
    };
    let mut cg_table = Table::new(&["solver", "time", "iterations"]);
    let seq = time_budget("cg sequential", budget, 10, || {
        let mut worst = 0usize;
        for c in 0..nrhs {
            let r = cg(&shifted, &rhs[c * n..(c + 1) * n], opts);
            worst = worst.max(r.iterations);
        }
        worst
    });
    let blk = time_budget("cg block", budget, 10, || {
        cg_block(&shifted, &rhs, nrhs, opts).iterations
    });
    let iters = cg_block(&shifted, &rhs, nrhs, opts).iterations;
    cg_table.row(&[
        format!("{nrhs} sequential CG solves"),
        fmt_secs(seq.median_s),
        iters.to_string(),
    ]);
    cg_table.row(&[
        format!("block-CG ({nrhs} RHS)"),
        fmt_secs(blk.median_s),
        iters.to_string(),
    ]);
    println!(
        "\nBlock-CG vs sequential CG (B = {nrhs}, tol = {:.0e}) — speedup {:.2}x\n",
        opts.tol,
        seq.median_s / blk.median_s.max(1e-12)
    );
    cg_table.print();
    cg_table.write_csv("batch_cg");
    println!();
}

//! Fig. 4 — MVM cosine error of Simplex-GP vs the exact MVM, per blur
//! stencil order r, per benchmark dataset. (Paper: errors in the
//! 1e-3..1e-1 band; increasing r does NOT monotonically reduce error
//! because blur truncation interacts with the spacing.)

use simplex_gp::datasets::{generate, split_standardize, PAPER_DATASETS};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::mvm::{ExactMvm, MvmOperator};
use simplex_gp::util::bench::Table;
use simplex_gp::util::stats::cosine_error;
use simplex_gp::util::Pcg64;

fn main() {
    let quick = simplex_gp::util::bench::quick_mode();
    let n = if quick { 1000 } else { 4000 };
    let orders = [1usize, 2, 3];
    let mut table = Table::new(&["dataset", "d", "r1", "r2", "r3"]);
    for spec in PAPER_DATASETS {
        let ds = generate(spec.name, n, 0);
        let sp = split_standardize(&ds, 1);
        let x = &sp.train.x;
        let nn = sp.train.n();
        let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, spec.d, 1.0);
        let exact = ExactMvm::new(&kernel, x, spec.d);
        let mut rng = Pcg64::new(3);
        let v = rng.normal_vec(nn);
        let base = exact.mvm(&v);
        let mut cells = vec![spec.name.to_string(), spec.d.to_string()];
        for r in orders {
            let lat = PermutohedralLattice::build(x, spec.d, &kernel, r);
            let err = cosine_error(&lat.mvm(&v), &base);
            cells.push(format!("{err:.2e}"));
        }
        table.row(&cells);
    }
    println!("\nFig. 4 — MVM cosine error 1 - <z,z^>/(|z||z^|) vs exact, n = {n}\n");
    table.print();
    table.write_csv("fig4_mvm_error");
    println!("\nShape check: errors sit in the paper's 1e-3..1e-1 band and higher r is\nnot uniformly better (blur truncation effect the paper calls out).\n");
}

//! Streaming-ingest cost vs full rebuild (the PR-4 acceptance bench):
//! appending k ∈ {1, 64, 1024} points to a built n = 50k, d = 4 lattice
//! via [`PermutohedralLattice::ingest`] against rebuilding from scratch
//! on the n + k point set.
//!
//! Why ingest wins: a rebuild re-embeds and re-interns all n + k points
//! (O(n·(d+1)) hash inserts) and re-resolves the entire blur adjacency
//! (O(m·(d+1)·2r) lookups); ingest embeds only the k new points,
//! interns only the keys they introduce, and patches adjacency for
//! those keys alone (plus one dense relayout copy). Acceptance: the
//! 64-point ingest is ≥ 5× faster than the rebuild.
//!
//! Each timed ingest starts from a `Clone` of the base lattice so the
//! measured work is exactly one incremental batch; the clone cost is
//! timed separately and reported as a reference column (it never counts
//! against the ingest).
//!
//! A second sweep (PR 9) measures the **warm-started** streaming solve:
//! [`SimplexGp::ingest`] seeds the post-ingest CG solve with the old α
//! zero-extended over the spliced rows, against a cold twin that runs
//! [`SimplexGp::ingest_patch`] + [`SimplexGp::resolve_alpha`] from a
//! zero guess on the identical model. Both absorb the same batch into
//! the same lattice — the delta is purely the initial guess, and shows
//! up as fewer CG iterations (the invariants suite pins the strict
//! inequality and the ≤ 1e-10 α match; here we report the trajectory).
//!
//! With `SIMPLEX_GP_BENCH_JSON=<path>` set (CI bench-smoke), every row
//! is appended to the perf-trajectory file as
//! `{"bench": "ingest", "n", "d", "k", "new_keys", "ns_ingest",
//!   "ns_rebuild", "speedup"}` and
//! `{"bench": "ingest_warm", "n", "d", "k", "shards", "warm_iters",
//!   "cold_iters", "ns_warm", "ns_cold"}`.
//!
//!     cargo bench --bench ingest [-- --quick]

use simplex_gp::gp::{GpConfig, SimplexGp};
use simplex_gp::kernels::{ArdKernel, KernelFamily};
use simplex_gp::lattice::PermutohedralLattice;
use simplex_gp::util::bench::{
    append_bench_json, bench_record, fmt_secs, quick_mode, time_fn, Table,
};
use simplex_gp::util::Pcg64;

fn main() {
    let quick = quick_mode();
    // The acceptance regime is pinned at n = 50k, d = 4 (ISSUE 4); quick
    // mode keeps n and trims repetitions instead.
    let n: usize = 50_000;
    let d = 4;
    let iters = if quick { 3 } else { 10 };
    let kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);

    let mut rng = Pcg64::new(71);
    let x = rng.normal_vec(n * d);
    let extra = rng.normal_vec(1024 * d);

    let (t_base, base) = time_fn("base build", 0, 1, || {
        PermutohedralLattice::build(&x, d, &kernel, 1)
    });
    println!(
        "base lattice: n = {n}, d = {d}, m = {} built in {}\n",
        base.m,
        fmt_secs(t_base.median_s)
    );

    let mut table = Table::new(&[
        "k",
        "ingest",
        "rebuild",
        "speedup",
        "new keys",
        "clone (ref)",
    ]);
    let mut speedup_at_64 = 0.0f64;
    for &k in &[1usize, 64, 1024] {
        let batch = &extra[..k * d];

        // Clone cost reference (not part of the timed ingest).
        let (t_clone, _) = time_fn("clone", 1, iters, || base.clone());

        // Pre-clone a pool of base lattices and time PURE ingest on
        // each (cloning inside the timed closure would charge the copy
        // to the ingest).
        let mut pool: Vec<PermutohedralLattice> =
            (0..iters + 1).map(|_| base.clone()).collect();
        let mut new_keys = 0usize;
        let mut samples = Vec::with_capacity(iters);
        for lat in pool.iter_mut() {
            let t0 = std::time::Instant::now();
            let nk = lat.ingest(batch, &kernel);
            samples.push(t0.elapsed().as_secs_f64());
            new_keys = nk;
        }
        samples.remove(0); // warmup
        samples.sort_by(f64::total_cmp);
        let ingest_s = samples[samples.len() / 2];

        // Rebuild cost at the final point set.
        let mut full_x = x.clone();
        full_x.extend_from_slice(batch);
        let (t_rebuild, rebuilt) = time_fn("rebuild", 0, iters.min(3), || {
            PermutohedralLattice::build(&full_x, d, &kernel, 1)
        });
        let rebuild_s = t_rebuild.median_s;

        // Equivalence spot check: the ingested lattice IS the rebuilt
        // one (bitwise — the invariants suite pins this exhaustively).
        let ingested = &pool[1];
        assert_eq!(ingested.m, rebuilt.m, "k={k}: m mismatch");
        assert_eq!(ingested.offsets, rebuilt.offsets, "k={k}: offsets mismatch");
        let mut vrng = Pcg64::new(72);
        let v = vrng.normal_vec(n + k);
        let (ui, uf) = (ingested.mvm(&v), rebuilt.mvm(&v));
        for i in 0..n + k {
            assert_eq!(ui[i].to_bits(), uf[i].to_bits(), "k={k}: mvm row {i}");
        }

        let speedup = rebuild_s / ingest_s.max(1e-12);
        if k == 64 {
            speedup_at_64 = speedup;
        }
        table.row(&[
            k.to_string(),
            fmt_secs(ingest_s),
            fmt_secs(rebuild_s),
            format!("{speedup:.1}x"),
            new_keys.to_string(),
            fmt_secs(t_clone.median_s),
        ]);
        append_bench_json(&bench_record(
            "ingest",
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("k", k as f64),
                ("new_keys", new_keys as f64),
                ("ns_ingest", ingest_s * 1e9),
                ("ns_rebuild", rebuild_s * 1e9),
                ("speedup", speedup),
            ],
        ));
    }

    println!("Streaming ingest vs full rebuild at n = {n}, d = {d}\n");
    table.print();
    table.write_csv("ingest");

    println!(
        "\nacceptance: 64-point ingest is {speedup_at_64:.1}x faster than a rebuild {}",
        if speedup_at_64 >= 5.0 {
            "(>= 5x: PASS)"
        } else {
            "(< 5x: FAIL)"
        }
    );

    // ---- Warm-started streaming solve vs cold re-solve (PR 9) ----
    //
    // Model-level: the GP solve dominates the ingest cost once α must
    // be refreshed, so this sweep runs at a solve-bound size (n = 4096)
    // with a tolerance tight enough that the seed's head start is
    // visible in the iteration count.
    let n_gp: usize = 4096;
    let shards = 2usize;
    let gp_kernel = ArdKernel::with_lengthscale(KernelFamily::Rbf, d, 0.5);
    let gp_cfg = GpConfig {
        shards,
        cg_tol: 1e-6,
        ..GpConfig::default()
    };
    let mut grng = Pcg64::new(73);
    let gx: Vec<f64> = (0..n_gp * d).map(|_| grng.uniform_in(-2.0, 2.0)).collect();
    let gy: Vec<f64> = (0..n_gp)
        .map(|i| (gx[i * d]).sin() + 0.05 * grng.normal())
        .collect();
    let gp_extra: Vec<f64> = (0..1024 * d).map(|_| grng.uniform_in(-2.0, 2.0)).collect();
    let gp_extra_y: Vec<f64> = (0..1024)
        .map(|i| (gp_extra[i * d]).sin() + 0.05 * grng.normal())
        .collect();
    let refit = || {
        SimplexGp::fit(&gx, &gy, d, gp_kernel.clone(), 0.05, gp_cfg.clone()).unwrap()
    };

    let mut warm_table = Table::new(&["k", "warm", "cold", "warm iters", "cold iters"]);
    let mut all_fewer = true;
    for &k in &[1usize, 64, 1024] {
        let (xb, yb) = (&gp_extra[..k * d], &gp_extra_y[..k]);

        // Warm: plain `ingest` — the spliced α seeds the solve.
        let mut warm = refit();
        let t0 = std::time::Instant::now();
        warm.ingest(xb, yb).unwrap();
        let warm_s = t0.elapsed().as_secs_f64();
        let warm_iters = warm.fit_iterations;
        assert!(warm.last_solve_warm(), "k={k}: ingest solve was not warm");

        // Cold: identical patch, then a zero-seeded re-solve.
        let mut cold = refit();
        let t0 = std::time::Instant::now();
        cold.ingest_patch(xb, yb).unwrap();
        cold.resolve_alpha();
        let cold_s = t0.elapsed().as_secs_f64();
        let cold_iters = cold.fit_iterations;
        assert!(!cold.last_solve_warm(), "k={k}: cold re-solve was seeded");

        // Same model either way — the guess changes the path, not the
        // destination (the invariants suite pins the α match).
        assert_eq!(warm.n_train(), cold.n_train(), "k={k}: n diverged");
        all_fewer &= warm_iters < cold_iters;
        warm_table.row(&[
            k.to_string(),
            fmt_secs(warm_s),
            fmt_secs(cold_s),
            warm_iters.to_string(),
            cold_iters.to_string(),
        ]);
        append_bench_json(&bench_record(
            "ingest_warm",
            &[
                ("n", n_gp as f64),
                ("d", d as f64),
                ("k", k as f64),
                ("shards", shards as f64),
                ("warm_iters", warm_iters as f64),
                ("cold_iters", cold_iters as f64),
                ("ns_warm", warm_s * 1e9),
                ("ns_cold", cold_s * 1e9),
            ],
        ));
    }

    println!("\nWarm-seeded ingest solve vs cold re-solve at n = {n_gp}, d = {d}, P = {shards}\n");
    warm_table.print();
    warm_table.write_csv("ingest_warm");
    println!(
        "\nwarm restarts: warm iterations strictly fewer at every k: {}",
        if all_fewer { "PASS" } else { "FAIL (see invariants suite)" }
    );
}
